"""Hybrid stochastic-binary pipeline (§IV + §V.B): pretrain → quantize first
layer → freeze → retrain the binary remainder.

This is the paper's third contribution: the binary-domain retraining absorbs
the noise injected by the short-stream stochastic first layer.  The first
layer is *frozen* during retraining ("retraining the binary portion"), so no
straight-through estimator is required on the main path; an optional STE mode
(beyond-paper) fine-tunes the first-layer weights through the quantizer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sc_layer import SCConfig
from repro.models import lenet
from repro.train import optim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    mode: str = "sc"                 # "sc" | "binary" | "float"
    sc: SCConfig = SCConfig()
    bits: int = 4                    # binary-baseline quantization bits
    soft_threshold: float = 0.0
    sc_impl: str = "table"           # "table" | "streams"


def loss_fn(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


# --------------------------------------------------------------------------
# Stage 1 — float pretraining (paper: TF/Keras on a Titan X; here: pure JAX).
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "opt_cfg"))
def float_train_step(params, opt_state, x, y, key,
                     cfg: lenet.LeNetConfig, opt_cfg: optim.AdamWConfig):
    def loss(p):
        logits = lenet.apply(p, x, cfg, mode="float", train=True,
                             dropout_key=key)
        return loss_fn(logits, y)
    l, grads = jax.value_and_grad(loss)(params)
    params, opt_state = optim.apply(params, grads, opt_state, opt_cfg)
    return params, opt_state, l


# --------------------------------------------------------------------------
# Stage 2 — first-layer feature caching.
# The frozen front end means each design's layer-1 output can be precomputed
# once over the dataset; retraining then runs on cached {-1,0,1} features.
# --------------------------------------------------------------------------

def cache_first_layer(params, images: np.ndarray, hybrid: HybridConfig,
                      batch: int = 64) -> np.ndarray:
    """images: uint8 (n, 28, 28, 1).  Returns int8 (n, 28, 28, C1) features."""
    fwd = jax.jit(lambda xb: lenet.first_layer(
        params, xb, hybrid.mode, hybrid.sc, hybrid.bits,
        hybrid.soft_threshold, hybrid.sc_impl))
    outs = []
    for i in range(0, images.shape[0], batch):
        xb = jnp.asarray(images[i:i + batch], jnp.float32) / 255.0
        outs.append(np.asarray(fwd(xb), np.int8))
    return np.concatenate(outs, axis=0)


# --------------------------------------------------------------------------
# Stage 3 — retrain the binary tail on cached features.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "opt_cfg"))
def tail_train_step(params, opt_state, h1, y, key,
                    cfg: lenet.LeNetConfig, opt_cfg: optim.AdamWConfig):
    def loss(p):
        logits = lenet.tail({**params, **p}, h1, cfg, train=True,
                            dropout_key=key)
        return loss_fn(logits, y)
    trainable = {k: params[k] for k in ("conv2", "dense1", "dense2")}
    l, grads = jax.value_and_grad(loss)(trainable)
    trainable, opt_state = optim.apply(trainable, grads, opt_state, opt_cfg)
    return {**params, **trainable}, opt_state, l


def retrain_tail(params, feats: np.ndarray, labels: np.ndarray,
                 cfg: lenet.LeNetConfig, *, steps: int = 400, batch: int = 128,
                 lr: float = 1e-3, seed: int = 0):
    """Retrain conv2/dense1/dense2 on cached first-layer features."""
    opt_cfg = optim.AdamWConfig(lr=lr)
    trainable = {k: params[k] for k in ("conv2", "dense1", "dense2")}
    opt_state = optim.init(trainable, opt_cfg)
    key = jax.random.key(seed)
    n = feats.shape[0]
    for step in range(steps):
        rng = np.random.default_rng((seed, step))
        idx = rng.integers(0, n, size=batch)
        key, sub = jax.random.split(key)
        params, opt_state, _ = tail_train_step(
            params, opt_state, jnp.asarray(feats[idx], jnp.float32),
            jnp.asarray(labels[idx]), sub, cfg, opt_cfg)
    return params


def evaluate_cached(params, feats: np.ndarray, labels: np.ndarray,
                    cfg: lenet.LeNetConfig, batch: int = 256) -> float:
    """Classification accuracy from cached first-layer features."""
    fwd = jax.jit(lambda h: lenet.tail(params, h, cfg, train=False))
    correct = 0
    for i in range(0, feats.shape[0], batch):
        logits = fwd(jnp.asarray(feats[i:i + batch], jnp.float32))
        correct += int((np.asarray(jnp.argmax(logits, -1))
                        == labels[i:i + batch]).sum())
    return correct / feats.shape[0]


def evaluate(params, images: np.ndarray, labels: np.ndarray,
             cfg: lenet.LeNetConfig, hybrid: HybridConfig,
             batch: int = 256) -> float:
    """End-to-end accuracy of a hybrid design on raw uint8 images."""
    fwd = jax.jit(lambda xb: lenet.apply(
        params, xb, cfg, mode=hybrid.mode, sc_cfg=hybrid.sc, bits=hybrid.bits,
        soft_threshold=hybrid.soft_threshold, sc_impl=hybrid.sc_impl))
    correct = 0
    for i in range(0, images.shape[0], batch):
        xb = jnp.asarray(images[i:i + batch], jnp.float32) / 255.0
        logits = fwd(xb)
        correct += int((np.asarray(jnp.argmax(logits, -1))
                        == labels[i:i + batch]).sum())
    return correct / images.shape[0]


# --------------------------------------------------------------------------
# Beyond-paper: straight-through estimator fine-tuning of the SC first layer.
# The forward pass is the exact SC simulation; the backward pass treats the
# quantize+sign chain as identity within [-1, 1].
# --------------------------------------------------------------------------

@jax.custom_vjp
def ste_sign(x):
    return jnp.where(x == 0, 0.0, jnp.sign(x))


def _ste_fwd(x):
    return ste_sign(x), x


def _ste_bwd(x, g):
    return (jnp.where(jnp.abs(x) <= 1.0, g, 0.0),)


ste_sign.defvjp(_ste_fwd, _ste_bwd)
