"""Analytical gate-level energy / power / area model (Table 3 reproduction).

We cannot synthesize a 65nm ASIC here, so this module is a *calibrated
analytical model* with the physically-correct functional forms, whose few free
constants are fit to the paper's own Table 3 numbers (Synopsys DC/ICC/PrimeTime,
65nm TSMC).  The model's structure — not just a table copy — is what lets us
extrapolate to other first layers (whisper / VLM frontends) in the beyond-paper
experiments:

  Frame time      T(b)      = T_CYCLE · 2^b · PASSES          (streams of N=2^b)
  SC power        P_sc(b)   = P_SC0 · α(b)                    (α = activity factor,
                                                               dips for b<=3)
  SC energy       E_sc(b)   = P_sc(b) · T(b)                  (∝ N, the paper's
                                                               exponential saving)
  Binary energy   E_bin(b)  = (E0 + E1·b) per frame           (MAC energy ∝ datapath
                                                               width)
  Binary power    P_bin(b)  = E_bin(b) / T(b)                 (throughput-normalized:
                                                               binary must clock 2^-b
                                                               faster to keep up)
  Area            A_bin(b)  = AB0 + AB1·b   (datapath width)
                  A_sc(b)   = AS0 + AS1·b   (counter width + SNG grow with b)

Internal consistency of the paper's table (which the fit exploits):
``E/P = T`` holds exactly for every column of both designs with
``T(8) = 16.38 µs`` — i.e. the published numbers *are* this model.

Gate-level breakdown: the SC convolution engine of Fig. 3 has, per dot-product
unit, 2·K AND multipliers (pos/neg split), 2·(2^ceil(log2 K) - 1) TFF adders,
and 2 asynchronous counters; 784 units run in parallel and the SNG bank is
amortized across them.  P_SC0 is distributed over this inventory with nominal
65nm per-gate switching energies so component shares can be reported.
"""
from __future__ import annotations

import dataclasses

import numpy as np

BITS = np.arange(2, 9)  # supported precisions, 2..8

# ---- Calibrated constants (fit to Table 3; see fit report in benchmarks) ----
T_FRAME_8BIT_US = 16.383  # µs per frame at 8-bit (543.42 nJ / 33.17 mW)
P_SC0_MW = 33.17          # SC power plateau (mW)
# activity factor α(b): SC switching activity dips for very short streams
_ALPHA = {8: 1.0, 7: 1.0115, 6: 1.0027, 5: 0.9952, 4: 1.0009, 3: 0.9032, 2: 0.8547}
# binary per-frame energy: dominated by the b-bit multiplier array —
# quadratic in b with a large linear term (adders/registers), LSq on Table 3
E_BIN0_NJ, E_BIN1_NJ, E_BIN2_NJ = 19.373, 76.446, 0.6825
# area models (mm^2, 65nm): binary multiplier array is O(b^2)
A_BIN0, A_BIN1, A_BIN2 = 0.036929, 0.092905, 0.0083095
A_SC0, A_SC1 = 0.9666, 0.0437     # SC: counter/SNG widths ∝ b (array ~flat)

# ---- Structural gate inventory (Fig. 3 engine; LeNet-5 first layer) ----
N_UNITS = 784            # parallel dot-product units (one per output pixel)
N_KERNELS = 32           # first-layer kernels (weight passes per frame)
K_WINDOW = 25            # 5x5 window -> K products per dot product
# nominal 65nm switching energies (fJ per gate per cycle) — relative weights
# used to split P_SC0 into component shares; absolute scale is calibrated.
_FJ = {"and": 1.0, "tff": 6.0, "counter_bit": 4.0, "sng_bit": 5.0}


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    bits: int
    frame_time_us: float
    sc_power_mw: float
    sc_energy_nj: float
    bin_power_mw: float
    bin_energy_nj: float
    sc_area_mm2: float
    bin_area_mm2: float

    @property
    def efficiency_gain(self) -> float:
        """Binary-over-SC energy ratio (paper: 9.8x at 4-bit, ~1x at 8-bit)."""
        return self.bin_energy_nj / self.sc_energy_nj


def frame_time_us(bits: int) -> float:
    return T_FRAME_8BIT_US * 2.0 ** (bits - 8)


def sc_power_mw(bits: int) -> float:
    return P_SC0_MW * _ALPHA[bits]


def sc_energy_nj(bits: int) -> float:
    return sc_power_mw(bits) * frame_time_us(bits)  # mW * µs = nJ


def bin_energy_nj(bits: int) -> float:
    return E_BIN0_NJ + E_BIN1_NJ * bits + E_BIN2_NJ * bits * bits


def bin_power_mw(bits: int) -> float:
    return bin_energy_nj(bits) / frame_time_us(bits)


def sc_area_mm2(bits: int) -> float:
    return A_SC0 + A_SC1 * bits


def bin_area_mm2(bits: int) -> float:
    return A_BIN0 + A_BIN1 * bits + A_BIN2 * bits * bits


def report(bits: int) -> EnergyReport:
    if not 2 <= bits <= 8:
        raise ValueError("model calibrated for 2..8 bits")
    return EnergyReport(
        bits=bits,
        frame_time_us=frame_time_us(bits),
        sc_power_mw=sc_power_mw(bits),
        sc_energy_nj=sc_energy_nj(bits),
        bin_power_mw=bin_power_mw(bits),
        bin_energy_nj=bin_energy_nj(bits),
        sc_area_mm2=sc_area_mm2(bits),
        bin_area_mm2=bin_area_mm2(bits),
    )


def component_shares(bits: int) -> dict[str, float]:
    """Split SC power into gate-class shares (relative 65nm weights)."""
    depth_leaves = 1 << int(np.ceil(np.log2(K_WINDOW)))
    n_and = 2 * K_WINDOW * N_UNITS
    n_tff = 2 * (depth_leaves - 1) * N_UNITS
    n_cnt_bits = 2 * bits * N_UNITS
    n_sng_bits = bits * (K_WINDOW + 1)      # weight SNG bank, amortized
    raw = {
        "and_multipliers": n_and * _FJ["and"],
        "tff_adders": n_tff * _FJ["tff"],
        "counters": n_cnt_bits * _FJ["counter_bit"],
        "sng_bank": n_sng_bits * _FJ["sng_bit"],
    }
    total = sum(raw.values())
    return {k: v / total for k, v in raw.items()}


def scaled_report(bits: int, k_window: int, n_units: int, n_kernels: int
                  ) -> EnergyReport:
    """Beyond-paper: project the model to a different first layer.

    Scales SC power with the gate inventory and binary energy with MAC count,
    keeping the calibrated 65nm per-gate constants.  Used to project
    near-sensor savings for the whisper / VLM frontends.
    """
    base = report(bits)
    gate_scale = (k_window * n_units) / float(K_WINDOW * N_UNITS)
    pass_scale = n_kernels / float(N_KERNELS)
    return EnergyReport(
        bits=bits,
        frame_time_us=base.frame_time_us * pass_scale,
        sc_power_mw=base.sc_power_mw * gate_scale,
        sc_energy_nj=base.sc_energy_nj * gate_scale * pass_scale,
        bin_power_mw=base.bin_power_mw * gate_scale,
        bin_energy_nj=base.bin_energy_nj * gate_scale * pass_scale,
        sc_area_mm2=base.sc_area_mm2 * gate_scale,
        bin_area_mm2=base.bin_area_mm2 * gate_scale,
    )


# Paper's Table 3 ground truth (for benchmark deltas).
PAPER_TABLE3 = {
    # bits: (bin_pwr_mw, sc_pwr_mw, bin_nj, sc_nj, bin_mm2, sc_mm2)
    8: (40.95, 33.17, 670.92, 543.42, 1.313, 1.321),
    7: (72.80, 33.55, 596.38, 274.82, 1.094, 1.282),
    6: (121.52, 33.26, 497.74, 136.22, 0.891, 1.240),
    5: (204.96, 33.01, 419.76, 67.60, 0.710, 1.200),
    4: (325.36, 33.20, 333.17, 34.00, 0.543, 1.166),
    3: (501.76, 29.96, 256.90, 15.34, 0.391, 1.110),
    2: (683.20, 28.35, 174.90, 7.26, 0.255, 1.057),
}
