"""Stochastic arithmetic primitives.

Three levels of fidelity for every circuit, all bit-exact to one another
(proved by tests):

  1. ``*_gate``   — cycle-exact gate-level simulation (``lax.scan`` over clock
                    cycles on unpacked bits).  The ground truth; matches the
                    paper's Fig. 1/Fig. 2 schematics wire-for-wire.
  2. ``*_packed`` — bit-packed word-parallel implementation (uint32 lanes).
                    This is the TPU-native datapath: 32 ASIC cycles per VPU op.
  3. count-domain identities — for the TFF adder the output *popcount* is a
                    closed-form function of the input popcounts
                    (``(c_x + c_y + s0) >> 1``), so whole adder *trees* reduce
                    to integer arithmetic.  This is what the Pallas kernel and
                    the large-scale functional simulation use.

The new TFF adder (paper Fig. 2b) semantics, per clock cycle:
    if x_t == y_t: z_t = x_t            (TFF state unchanged)
    else:          z_t = state; state = !state
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitstream
from repro.core.bitstream import WORD

# --------------------------------------------------------------------------
# Multipliers (unipolar): AND gate.
# --------------------------------------------------------------------------

def mult(x: jax.Array, y: jax.Array) -> jax.Array:
    """Unipolar stochastic multiplier (Fig. 1a): bitwise AND of packed streams."""
    return jnp.bitwise_and(x, y)


# --------------------------------------------------------------------------
# Old adders.
# --------------------------------------------------------------------------

def or_add(x: jax.Array, y: jax.Array) -> jax.Array:
    """OR-gate 'adder' — accurate only near zero [Li et al., FPGA'16]."""
    return jnp.bitwise_or(x, y)


def mux_add(x: jax.Array, y: jax.Array, select: jax.Array) -> jax.Array:
    """Conventional scaled adder (Fig. 1b): MUX with p=1/2 select stream.

    ``p_z = 0.5 (p_x + p_y)`` in expectation; the select stream discards half
    of each input's bits, which is the accuracy loss Table 2 quantifies.
    """
    return (x & select) | (y & ~select)


def tff_select_stream(length: int) -> jax.Array:
    """A TFF toggling every cycle: 0101... — deterministic p=1/2 select."""
    w = bitstream.n_words(length)
    word = np.uint32(0xAAAAAAAA)  # bit t set iff t odd -> toggles each cycle
    packed = np.full((w,), word, dtype=np.uint32) & bitstream.word_masks(length)
    return jnp.asarray(packed)


# --------------------------------------------------------------------------
# New TFF adder (paper Fig. 2b) — cycle-exact gate-level reference.
# --------------------------------------------------------------------------

def tff_add_gate(x_bits: jax.Array, y_bits: jax.Array, s0: jax.Array | int = 0
                 ) -> tuple[jax.Array, jax.Array]:
    """Cycle-exact TFF adder on unpacked bool streams ``(..., N)``.

    Returns ``(z_bits, final_state)``.  ``s0`` selects the rounding direction
    (Fig. 2c): s0=0 rounds down, s0=1 rounds up when (c_x+c_y) is odd.
    """
    x_bits = x_bits.astype(jnp.bool_)
    y_bits = y_bits.astype(jnp.bool_)
    state0 = jnp.broadcast_to(jnp.asarray(s0, jnp.bool_), x_bits.shape[:-1])

    def cycle(state, xy):
        xt, yt = xy
        differ = xt ^ yt
        z = jnp.where(differ, state, xt)
        new_state = jnp.where(differ, ~state, state)
        return new_state, z

    xs = jnp.moveaxis(x_bits, -1, 0)
    ys = jnp.moveaxis(y_bits, -1, 0)
    final_state, zs = jax.lax.scan(cycle, state0, (xs, ys))
    return jnp.moveaxis(zs, 0, -1), final_state


# --------------------------------------------------------------------------
# New TFF adder — packed word-parallel implementation.
#
# At positions where x == y the output equals x.  At the j-th differing
# position (0-indexed, in stream order) the output is s0 XOR (j mod 2).
# So we need the *exclusive prefix parity* of d = x ^ y at every bit —
# computed with the classic log-step XOR-shift trick inside each word plus a
# carried parity across words.
# --------------------------------------------------------------------------

def _prefix_parity_exclusive(d: jax.Array) -> jax.Array:
    """Exclusive prefix parity of set bits of ``d`` along the packed bit order.

    ``d``: uint32 ``(..., n_words)``.  Returns uint32 of the same shape where
    bit ``t`` = parity of the number of set bits of ``d`` strictly before
    stream position ``t``.
    """
    # Inclusive prefix parity within each word.
    p = d
    for shift in (1, 2, 4, 8, 16):
        p = p ^ (p << shift)
    # p now holds inclusive parity; exclusive within-word parity:
    excl = p ^ d
    # Parity carried in from all previous words: cumulative XOR of word parities.
    word_par = jnp.bitwise_count(d).astype(jnp.uint32) & jnp.uint32(1)
    carry = jnp.cumsum(word_par, axis=-1) - word_par  # exclusive cumsum
    carry = (carry & jnp.uint32(1)).astype(jnp.uint32)
    # A carried-in 1 flips every bit position of that word's exclusive parity.
    return excl ^ (jnp.uint32(0) - carry)  # 0 -> 0x0, 1 -> 0xFFFFFFFF


def tff_add_packed(x: jax.Array, y: jax.Array, length: int, s0: int = 0
                   ) -> tuple[jax.Array, jax.Array]:
    """Packed TFF adder, bit-exact to :func:`tff_add_gate`.

    Returns ``(z_packed, final_state)`` where ``final_state`` is int32 in {0,1}.
    """
    d = x ^ y
    par = _prefix_parity_exclusive(d)        # parity of differs before each bit
    # Output at differing position = s0 XOR parity; elsewhere = x (== y there).
    toggled = par if not s0 else ~par
    z = (x & y) | (d & toggled)
    masks = jnp.asarray(bitstream.word_masks(length))
    z = z & masks
    total_d = bitstream.popcount(d & masks)
    final_state = jnp.asarray(s0, jnp.int32) ^ (total_d & 1)
    return z, final_state


def tff_add_count(c_x: jax.Array, c_y: jax.Array, s0) -> jax.Array:
    """Count-domain identity for the TFF adder output popcount.

    ``c_z = floor((c_x + c_y)/2)`` for s0=0 and ``ceil`` for s0=1, i.e.
    ``(c_x + c_y + s0) >> 1``.  Exact — see tests for the proof against the
    gate-level scan.
    """
    return (c_x + c_y + jnp.asarray(s0, c_x.dtype if hasattr(c_x, "dtype") else jnp.int32)) >> 1


# --------------------------------------------------------------------------
# Adder trees.
#
# A k-level binary tree of TFF adders sums 2^k streams with scale 2^-k.
# Because each node's output count depends only on its input counts and its
# own initial state, the whole tree collapses to integer arithmetic in the
# count domain — the foundation of the fast functional path and the Pallas
# kernel.  ``s0_mode`` fixes each node's initial TFF state:
#   "zero"  — all round down (systematic downward bias ~ -0.5 LSB/level)
#   "one"   — all round up
#   "alt"   — alternate by node index within each level (bias ~ 0)
# --------------------------------------------------------------------------

def _node_s0(mode: str, level: int, index: jax.Array) -> jax.Array:
    if mode == "zero":
        return jnp.zeros_like(index)
    if mode == "one":
        return jnp.ones_like(index)
    if mode == "alt":
        return (index + level) & 1
    raise ValueError(f"unknown s0_mode {mode}")


def tff_tree_counts(counts: jax.Array, s0_mode: str = "alt") -> jax.Array:
    """Reduce ``(..., M)`` leaf popcounts through a TFF adder tree -> ``(...,)``.

    M is padded to the next power of two with zero streams (count 0), exactly
    as fixed hardware trees pad unused leaves.  Output = popcount of the root
    stream; root value = (sum of leaf values) / 2^ceil(log2 M) up to the
    deterministic per-node rounding.
    """
    M = counts.shape[-1]
    depth = max(1, int(np.ceil(np.log2(max(M, 2)))))
    pad = (1 << depth) - M
    if pad:
        counts = jnp.concatenate(
            [counts, jnp.zeros(counts.shape[:-1] + (pad,), counts.dtype)], axis=-1)
    c = counts
    for level in range(depth):
        left = c[..., 0::2]
        right = c[..., 1::2]
        idx = jnp.arange(left.shape[-1], dtype=c.dtype)
        s0 = _node_s0(s0_mode, level, idx)
        c = (left + right + s0) >> 1
    return c[..., 0]


def tff_tree_gate(streams: jax.Array, length: int, s0_mode: str = "alt"
                  ) -> jax.Array:
    """Gate-level TFF adder tree on packed streams ``(..., M, n_words)``.

    Returns the packed root stream.  Used only by tests/benchmarks to prove the
    count-domain tree identity; the production path is count-domain.
    """
    M = streams.shape[-2]
    depth = max(1, int(np.ceil(np.log2(max(M, 2)))))
    pad = (1 << depth) - M
    if pad:
        z = bitstream.zeros(streams.shape[:-2] + (pad,), length)
        streams = jnp.concatenate([streams, z], axis=-2)
    s = streams
    for level in range(depth):
        left = s[..., 0::2, :]
        right = s[..., 1::2, :]
        outs = []
        for i in range(left.shape[-2]):
            s0 = int(_node_s0(s0_mode, level, jnp.asarray(i)))
            z, _ = tff_add_packed(left[..., i, :], right[..., i, :], length, s0=s0)
            outs.append(z)
        s = jnp.stack(outs, axis=-2)
    return s[..., 0, :]


def mux_tree_counts(streams: jax.Array, length: int, select_codes: np.ndarray,
                    ) -> jax.Array:
    """Old-style MUX adder tree on packed streams ``(..., M, n_words)``.

    Each level uses an independent p=1/2 select stream derived from
    ``select_codes`` (one code sequence per level, lagged), modelling the
    conventional design's extra random sources.  Returns root popcounts.
    """
    M = streams.shape[-2]
    depth = max(1, int(np.ceil(np.log2(max(M, 2)))))
    pad = (1 << depth) - M
    if pad:
        z = bitstream.zeros(streams.shape[:-2] + (pad,), length)
        streams = jnp.concatenate([streams, z], axis=-2)
    s = streams
    half = length // 2
    for level in range(depth):
        codes = np.roll(select_codes, 7 * level + 3)
        sel = bitstream.encode_comparator(jnp.asarray(half, jnp.int32),
                                          jnp.asarray(codes, jnp.int32), length)
        left = s[..., 0::2, :]
        right = s[..., 1::2, :]
        s = mux_add(left, right, sel)
    return bitstream.popcount(s[..., 0, :])


# --------------------------------------------------------------------------
# Stochastic -> binary conversion (Fig. 1d): a counter == popcount.
# The ASIC uses *asynchronous* ripple counters so the SC domain can be clocked
# faster than the counter settles; that timing concern has no TPU analogue —
# functionally it is exactly popcount (documented in DESIGN.md).
# --------------------------------------------------------------------------

def counter(packed: jax.Array) -> jax.Array:
    """Stochastic-to-binary converter: count the 1s."""
    return bitstream.popcount(packed)


def scaled_value(count: jax.Array, length: int, tree_depth: int) -> jax.Array:
    """Convert a root count back to an estimate of the *unscaled* sum.

    A depth-``k`` tree computes ``sum / 2^k`` — multiply back to undo it.
    """
    return count.astype(jnp.float32) * (2.0 ** tree_depth) / jnp.float32(length)
