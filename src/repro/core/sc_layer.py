"""The paper's stochastic first-layer (§IV.B): unipolar split-weight SC dot
product + sign activation.

Design (Fig. 3): weights are split into positive/negative unipolar streams
``w_pos``/``w_neg``; two dot products ``g_pos = x∘w_pos``, ``g_neg = x∘w_neg``
run entirely in the stochastic domain (AND multipliers + TFF adder tree), are
converted to binary by two counters, and a binary comparator implements the
sign activation — avoiding the bipolar encoding whose decision point sits at
maximum-fluctuation 0.5.

Three equivalent implementations of the *new* design (tested bit-identical):
  - ``counts_via_table``  — product popcounts via a precomputed (N+1)² lookup
                            table + count-domain TFF tree.  Fast functional
                            path used for training-time simulation at scale.
  - ``counts_via_streams``— materialize packed streams, AND, popcount, tree.
  - the Pallas kernel (``repro.kernels.sc_dot``) — packed AND+popcount GEMM.

The *old* design (prior-work baseline for Table 3's "Old SC" row) uses
LFSR-pair SNGs + MUX adder trees and only exists at stream level (the MUX
adder samples bit positions, so its output is not a function of input counts).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import arith, bitstream, sng


@dataclasses.dataclass(frozen=True)
class SCConfig:
    """Configuration of the stochastic first layer."""
    bits: int = 4                  # stream length N = 2**bits
    scheme: str = "ramp_lowdisc"   # SNG scheme for (activation, weight) streams
    s0_mode: str = "alt"           # TFF initial-state assignment in the tree
    adder: str = "tff"             # "tff" (paper's new) | "mux" (old) | "ideal"
    soft_threshold: float = 0.0    # |g_pos-g_neg| <= tau (value units) -> 0
    weight_scale: bool = True      # normalize kernels to full [-1,1] range

    @property
    def length(self) -> int:
        return 1 << self.bits


# --------------------------------------------------------------------------
# Product-count lookup table.
# popcount(S_a AND S_b) for deterministic SNG schemes is a pure function of
# the two levels (a, b) — precompute it once per (scheme, bits).
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def product_count_table(scheme: str, bits: int) -> np.ndarray:
    """(N+1, N+1) int32: popcount(stream_A(a) & stream_B(b)) for all levels."""
    N = 1 << bits
    codes_a, codes_b = sng.codes_for_scheme(scheme, bits)
    lv = np.arange(N + 1)
    bits_a = codes_a[None, :] < lv[:, None]     # (N+1, N)
    bits_b = codes_b[None, :] < lv[:, None]
    return np.einsum("an,bn->ab", bits_a.astype(np.int32), bits_b.astype(np.int32),
                     ).astype(np.int32)


# --------------------------------------------------------------------------
# Quantization.
# --------------------------------------------------------------------------

def quantize_levels(x01: jax.Array, bits: int) -> jax.Array:
    """Map [0,1] activations to integer stream levels 0..N."""
    N = 1 << bits
    return jnp.clip(jnp.round(x01 * N), 0, N).astype(jnp.int32)


def quantize_weights(w: jax.Array, bits: int, scale: bool = True
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Split weights into (pos_levels, neg_levels, per-kernel scale).

    ``w``: (..., K, O) float.  Weight scaling [Kim et al.] normalizes each
    output kernel to use the full dynamic range [-1, 1].
    """
    N = 1 << bits
    if scale:
        s = jnp.max(jnp.abs(w), axis=tuple(range(w.ndim - 1)), keepdims=True)
        s = jnp.maximum(s, 1e-8)
    else:
        s = jnp.ones((1,) * (w.ndim - 1) + (w.shape[-1],), w.dtype)
    wn = w / s
    pos = jnp.clip(jnp.round(jnp.maximum(wn, 0) * N), 0, N).astype(jnp.int32)
    neg = jnp.clip(jnp.round(jnp.maximum(-wn, 0) * N), 0, N).astype(jnp.int32)
    return pos, neg, s.reshape(s.shape[-1])


def dequantize_weights(pos: jax.Array, neg: jax.Array, scale: jax.Array,
                       bits: int) -> jax.Array:
    """Inverse of :func:`quantize_weights` (the value the SC layer 'sees')."""
    N = 1 << bits
    return (pos - neg).astype(jnp.float32) / N * scale


# --------------------------------------------------------------------------
# New-design dot product — count domain (fast functional path).
# --------------------------------------------------------------------------

def tree_depth(k: int) -> int:
    return max(1, int(np.ceil(np.log2(max(k, 2)))))


def counts_via_table(x_lvl: jax.Array, w_lvl: jax.Array, cfg: SCConfig
                     ) -> jax.Array:
    """Product popcounts by table lookup + TFF tree reduction.

    x_lvl: (..., K) int32 levels 0..N; w_lvl: (K, O) int32 levels.
    Returns root counts (..., O) int32 — one stochastic dot product per output.
    """
    table = jnp.asarray(product_count_table(cfg.scheme, cfg.bits))
    prod = table[x_lvl[..., :, None], w_lvl]           # (..., K, O)
    prod = jnp.swapaxes(prod, -1, -2)                  # (..., O, K)
    if cfg.adder == "ideal":
        k = x_lvl.shape[-1]
        return jnp.sum(prod, axis=-1) >> tree_depth(k)  # same 2^-d scaling
    return arith.tff_tree_counts(prod, s0_mode=cfg.s0_mode)


# --------------------------------------------------------------------------
# Stream-level dot products (ground truth + old-design baseline).
# --------------------------------------------------------------------------

def counts_via_streams(x_lvl: jax.Array, w_lvl: jax.Array, cfg: SCConfig
                       ) -> jax.Array:
    """Materialize packed streams and run the datapath bit-for-bit.

    Used by tests (must equal :func:`counts_via_table` exactly for the new
    design) and by the old-design baseline (``cfg.adder == "mux"``).
    """
    N = cfg.length
    bits = cfg.bits
    codes_a, codes_b = sng.codes_for_scheme(cfg.scheme, bits)
    sx = sng.generate(x_lvl, codes_a, N)               # (..., K, w)
    sw = sng.generate(w_lvl, codes_b, N)               # (K, O, w)
    prod = arith.mult(sx[..., :, None, :], sw)         # broadcast -> (..., K, O, w)
    # prod: (..., K, O, w) -> (..., O, K, w)
    prod = jnp.swapaxes(prod, -3, -2)
    if cfg.adder == "tff":
        counts = bitstream.popcount(prod)              # (..., O, K)
        return arith.tff_tree_counts(counts, s0_mode=cfg.s0_mode)
    if cfg.adder == "mux":
        sel_codes = sng.lfsr_sequence(bits)
        return arith.mux_tree_counts(prod, N, sel_codes)
    if cfg.adder == "ideal":
        counts = bitstream.popcount(prod)
        return jnp.sum(counts, axis=-1) >> tree_depth(x_lvl.shape[-1])
    raise ValueError(cfg.adder)


# --------------------------------------------------------------------------
# The full SC layer: g = sign(x ∘ w) with pos/neg split + soft threshold.
# --------------------------------------------------------------------------

def sc_dot_sign(x01: jax.Array, w: jax.Array, cfg: SCConfig,
                impl: str = "table") -> jax.Array:
    """Stochastic-domain ``sign(x∘w)`` exactly as in Fig. 3.

    x01: (..., K) activations in [0,1];  w: (K, O) float weights.
    Returns (..., O) float32 in {-1, 0, +1}.
    """
    x_lvl = quantize_levels(x01, cfg.bits)
    pos, neg, _scale = quantize_weights(w, cfg.bits, cfg.weight_scale)
    f = {"table": counts_via_table, "streams": counts_via_streams}[impl]
    if cfg.adder == "mux":                      # old design only exists at stream level
        f = counts_via_streams
    c_pos = f(x_lvl, pos, cfg)
    c_neg = f(x_lvl, neg, cfg)
    k = x01.shape[-1]
    # Undo the tree's 2^-depth scale and the 1/N stream scale -> value units.
    diff = (c_pos - c_neg).astype(jnp.float32) * (2.0 ** tree_depth(k)) / cfg.length
    thr = jnp.float32(cfg.soft_threshold)
    return jnp.where(jnp.abs(diff) <= thr, 0.0, jnp.sign(diff)).astype(jnp.float32)


def binary_dot_sign(x01: jax.Array, w: jax.Array, bits: int,
                    soft_threshold: float = 0.0, weight_scale: bool = True
                    ) -> jax.Array:
    """The all-binary baseline: k-bit quantized weights, 8-bit activations,
    exact integer dot product, sign activation (Table 3 'Binary' rows)."""
    x_lvl = quantize_levels(x01, 8).astype(jnp.int32)   # 8-bit sensor ADC
    pos, neg, _ = quantize_weights(w, bits, weight_scale)
    acc = jnp.einsum("...k,ko->...o", x_lvl.astype(jnp.float32),
                     (pos - neg).astype(jnp.float32))
    # value units: x_lvl/256 * w_lvl/N summed
    diff = acc / (256.0 * (1 << bits))
    thr = jnp.float32(soft_threshold)
    return jnp.where(jnp.abs(diff) <= thr, 0.0, jnp.sign(diff)).astype(jnp.float32)


# --------------------------------------------------------------------------
# Convolutional wrapper (im2col + sc_dot_sign) — the 784-unit engine.
# --------------------------------------------------------------------------

def extract_patches(x: jax.Array, ksize: int, padding: str = "SAME") -> jax.Array:
    """im2col: (B, H, W, C) -> (B, H', W', ksize*ksize*C)."""
    B, H, W, C = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, filter_shape=(ksize, ksize), window_strides=(1, 1), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return patches


def sc_conv2d_sign(x: jax.Array, w: jax.Array, cfg: SCConfig,
                   impl: str = "table", padding: str = "SAME") -> jax.Array:
    """Stochastic first-layer convolution.

    x: (B, H, W, C) in [0,1] (sensor data);  w: (kh, kw, C, O).
    Returns (B, H', W', O) in {-1, 0, +1}.
    """
    kh, kw, C, O = w.shape
    patches = extract_patches(x, kh, padding)
    return sc_dot_sign(patches, w.reshape(kh * kw * C, O), cfg, impl=impl)


def binary_conv2d_sign(x: jax.Array, w: jax.Array, bits: int,
                       soft_threshold: float = 0.0, padding: str = "SAME"
                       ) -> jax.Array:
    """All-binary quantized first-layer convolution baseline."""
    kh, kw, C, O = w.shape
    patches = extract_patches(x, kh, padding)
    return binary_dot_sign(patches, w.reshape(kh * kw * C, O), bits,
                           soft_threshold)
