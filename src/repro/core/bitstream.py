"""Bit-packed stochastic bit-stream representation.

A stochastic number (SN) of length ``N`` is stored as ``ceil(N/32)`` little-endian
``uint32`` words: bit ``t`` of the stream lives in word ``t // 32`` at bit position
``t % 32``.  The unipolar value of a stream is ``popcount / N``.

This is the TPU-native adaptation of the paper's serial bit-streams: 32 "clock
cycles" of the ASIC advance per vector word-op, and all SC gate primitives
(AND multiplier, MUX/TFF adders) become bitwise word ops on the VPU.

All functions are pure jnp and jit-safe.  ``N`` (stream length) is static.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32
UINT32_MASK = np.uint32(0xFFFFFFFF)


def n_words(length: int) -> int:
    """Number of uint32 words needed for a stream of ``length`` bits."""
    return (int(length) + WORD - 1) // WORD


def tail_mask(length: int) -> np.uint32:
    """Mask of valid bits in the final word of a length-``length`` stream."""
    rem = int(length) % WORD
    if rem == 0:
        return UINT32_MASK
    return np.uint32((1 << rem) - 1)


def word_masks(length: int) -> np.ndarray:
    """(n_words,) uint32 validity mask for each word of the stream."""
    w = n_words(length)
    masks = np.full((w,), UINT32_MASK, dtype=np.uint32)
    masks[-1] = tail_mask(length)
    return masks


def pack_bits(bits: jax.Array) -> jax.Array:
    """Pack a boolean/0-1 array ``(..., N)`` into ``(..., n_words(N))`` uint32.

    Bit ``t`` -> word ``t // 32``, position ``t % 32`` (LSB-first).
    """
    N = bits.shape[-1]
    w = n_words(N)
    pad = w * WORD - N
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), dtype=bits.dtype)], axis=-1
        )
    bits = bits.reshape(bits.shape[:-1] + (w, WORD)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum(bits * weights, axis=-1).astype(jnp.uint32)


def unpack_bits(packed: jax.Array, length: int) -> jax.Array:
    """Unpack ``(..., n_words)`` uint32 into boolean ``(..., length)``."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(packed.shape[:-1] + (packed.shape[-1] * WORD,))
    return bits[..., :length].astype(jnp.bool_)


def popcount(packed: jax.Array) -> jax.Array:
    """Total number of set bits over the trailing word axis -> int32 ``(...)``."""
    return jnp.sum(jnp.bitwise_count(packed).astype(jnp.int32), axis=-1)


def popcount_per_word(packed: jax.Array) -> jax.Array:
    """Per-word set-bit count, int32, same shape as ``packed``."""
    return jnp.bitwise_count(packed).astype(jnp.int32)


def encode_comparator(level: jax.Array, codes: jax.Array, length: int) -> jax.Array:
    """Comparator SNG (Fig. 1c of the paper): ``bit_t = codes[t] < level``.

    Args:
      level: integer array ``(...,)`` in ``[0, length]`` — the binary number to
        convert (``c`` ones in the output stream when ``codes`` is a permutation
        of ``0..length-1``).
      codes: ``(length,)`` integer code sequence (ramp, van-der-Corput, LFSR, ...).
      length: static stream length ``N``.

    Returns packed uint32 stream(s), shape ``(..., n_words(length))``.
    """
    level = jnp.asarray(level)
    bits = (codes[None, :] < level.reshape(-1)[:, None])
    packed = pack_bits(bits)
    return packed.reshape(level.shape + (n_words(length),))


def value(packed: jax.Array, length: int) -> jax.Array:
    """Unipolar value ``popcount / N`` as float32."""
    return popcount(packed).astype(jnp.float32) / jnp.float32(length)


def zeros(shape: tuple, length: int) -> jax.Array:
    """All-zero stream(s) (unipolar value 0)."""
    return jnp.zeros(tuple(shape) + (n_words(length),), dtype=jnp.uint32)


def ones(shape: tuple, length: int) -> jax.Array:
    """All-one stream(s) (unipolar value 1); tail bits beyond N are kept zero."""
    masks = jnp.asarray(word_masks(length))
    return jnp.broadcast_to(masks, tuple(shape) + (n_words(length),))
