"""Paper contribution: hybrid stochastic-binary arithmetic + first-layer NN."""
from repro.core.sc_layer import SCConfig  # noqa: F401
