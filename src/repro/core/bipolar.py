"""Bipolar stochastic arithmetic — and WHY the paper rejects it (§IV.B).

In the bipolar encoding a stream X represents ``2·p_X - 1 ∈ [-1, 1]``:
multiplication becomes XNOR, addition stays the scaled MUX/TFF tree.  It
handles negative weights directly — so why does the paper split weights into
two unipolar banks instead?

Because the sign activation's decision point (value 0) maps to unipolar
probability 0.5 — the point of MAXIMUM stream variance (Bernoulli variance
p(1-p) peaks at 0.5).  Exactly where the classifier must make its call, the
bipolar representation is noisiest (and toggles most, burning power).  The
split-unipolar design instead compares two binary counters, where the
decision is exact.  A second, subtler cost implemented here: a fixed adder
tree pads unused leaves with all-zero streams, which in bipolar encode value
-1 — a systematic bias the unipolar design doesn't have.

`tests/test_bipolar.py` quantifies both effects at matched stream length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import arith, bitstream, sng


def to_level(value: jax.Array, bits: int) -> jax.Array:
    """Bipolar value v ∈ [-1, 1] -> unipolar stream level round((v+1)/2·N)."""
    N = 1 << bits
    return jnp.clip(jnp.round((value + 1.0) * 0.5 * N), 0, N).astype(jnp.int32)


def from_count(count: jax.Array, length: int) -> jax.Array:
    """Bipolar value of a stream with ``count`` ones: 2c/N - 1."""
    return 2.0 * count.astype(jnp.float32) / length - 1.0


def mult(x: jax.Array, y: jax.Array, length: int) -> jax.Array:
    """Bipolar multiplier: XNOR (Gaines).  Tail bits kept zero."""
    masks = jnp.asarray(bitstream.word_masks(length))
    return (jnp.bitwise_xor(x, y) ^ masks) & masks


def dot_bipolar(x_val: jax.Array, w_val: jax.Array, bits: int,
                scheme: str = "ramp_lowdisc", s0_mode: str = "alt"
                ) -> jax.Array:
    """Bipolar stochastic dot product: estimate of ``Σ_k x_k·w_k``.

    x_val: (..., K) in [-1, 1]; w_val: (K, O) in [-1, 1].  XNOR products,
    TFF-tree summation (the adder is encoding-agnostic), zero-padded leaves
    un-biased analytically (each contributes bipolar -1).
    """
    N = 1 << bits
    K = x_val.shape[-1]
    codes_a, codes_b = sng.codes_for_scheme(scheme, bits)
    xs = sng.generate(to_level(x_val, bits), codes_a, N)      # (..., K, w)
    ws = sng.generate(to_level(w_val, bits), codes_b, N)      # (K, O, w)
    prod = mult(xs[..., :, None, :], ws, N)                   # (..., K, O, w)
    counts = bitstream.popcount(jnp.swapaxes(prod, -3, -2))   # (..., O, K)
    root = arith.tff_tree_counts(counts, s0_mode=s0_mode)     # (..., O)
    depth = max(1, int(np.ceil(np.log2(max(K, 2)))))
    pad = (1 << depth) - K
    # root bipolar value = (Σ_K v_i + pad·(-1)) / 2^depth
    return from_count(root, N) * (1 << depth) + pad


def sign_bipolar(x_val, w_val, bits, **kw) -> jax.Array:
    """sign(x∘w) through the bipolar path (the design the paper rejects)."""
    return jnp.sign(dot_bipolar(x_val, w_val, bits, **kw))


def decision_point_errors(bits: int, n: int = 512, K: int = 16, seed: int = 0):
    """Error of the dot estimate near the sign activation's decision point.

    Draws (x, w) with the exact dot pushed toward 0, returns
    (bipolar_abs_err, split_unipolar_abs_err) arrays for samples whose
    exact |dot| is in the smallest quartile — the regime §IV.B argues about.
    """
    from repro.core import sc_layer
    N = 1 << bits
    rng = np.random.default_rng(seed)
    x = rng.random((n, K)).astype(np.float32)              # sensor data [0,1]
    w = rng.normal(0, 0.25, (K, 1)).astype(np.float32)
    w = np.clip(w - (x @ w).mean() / K / np.maximum(x.mean(), 1e-6), -1, 1)
    exact = (x @ w)[:, 0]
    # bipolar path: encode x into [-1,1]
    est_b = np.asarray(dot_bipolar(jnp.asarray(2 * x - 1), jnp.asarray(w),
                                   bits))[:, 0]
    # bipolar estimate is of Σ (2x-1)w = 2Σxw - Σw: recover Σxw
    est_b = (est_b + w.sum()) / 2.0
    # split-unipolar path (the paper's design)
    cfg = sc_layer.SCConfig(bits=bits)
    xl = sc_layer.quantize_levels(jnp.asarray(x), bits)
    pos, neg, _ = sc_layer.quantize_weights(jnp.asarray(w), bits, scale=False)
    cp = sc_layer.counts_via_table(xl, pos, cfg)
    cn = sc_layer.counts_via_table(xl, neg, cfg)
    depth = sc_layer.tree_depth(K)
    est_s = (np.asarray(cp, np.float32)
             - np.asarray(cn, np.float32))[:, 0] * (2.0 ** depth) / N
    near0 = np.abs(exact) <= np.quantile(np.abs(exact), 0.25)
    return (np.abs(est_b - exact)[near0], np.abs(est_s - exact)[near0])
