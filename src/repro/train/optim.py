"""Optimizers as pure pytree transforms (no external deps).

AdamW with optional mixed precision: parameters may be bf16 while master
weights / moments are f32 (``state_dtype``).  The state pytree mirrors the
param pytree, so whatever sharding the params carry, the optimizer state
inherits leaf-for-leaf (plus any extra ZeRO sharding applied by
``repro.dist.sharding.zero_shard_rule``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0          # global-norm clip; 0 disables
    state_dtype: Any = jnp.float32  # moment dtype (bf16 halves optimizer HBM)
    master_dtype: Any = None        # f32 master copy when params are bf16


def init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, dtype=cfg.state_dtype)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }
    if cfg.master_dtype is not None:
        state["master"] = jax.tree.map(
            lambda p: p.astype(cfg.master_dtype), params)
    return state


def _global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def apply(params, grads, state, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state)."""
    step = state["step"] + 1
    if cfg.grad_clip > 0:
        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master=None):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        base = (master if master is not None else p).astype(jnp.float32)
        if cfg.weight_decay > 0:
            update = update + cfg.weight_decay * base
        new_master = base - cfg.lr * update
        return (new_master.astype(p.dtype),
                m_new.astype(cfg.state_dtype),
                v_new.astype(cfg.state_dtype),
                new_master)

    if "master" in state:
        out = jax.tree.map(upd, params, grads, state["m"], state["v"],
                           state["master"])
    else:
        out = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v),
                           params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    if "master" in state:
        new_state["master"] = jax.tree.map(
            lambda o: o[3].astype(cfg.master_dtype), out,
            is_leaf=lambda x: isinstance(x, tuple))
    return new_params, new_state


def sgd(params, grads, lr: float):
    """Plain SGD (used by a few small examples/tests)."""
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
