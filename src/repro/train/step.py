"""Training step factory: grad-accumulation scan + AdamW + optional int8
gradient compression, pjit-shardable.

Memory shape: the f32 grad accumulator and optimizer moments inherit the
params' FSDP+TP sharding (plus ZeRO-1 extension — see dist.sharding);
activations are bounded by (global_batch / microbatches) tokens in flight.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.train import optim
from repro.dist import compress as compress_lib
from repro.dist.sharding import hint


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    adamw: optim.AdamWConfig = optim.AdamWConfig(
        lr=3e-4, weight_decay=0.1, grad_clip=1.0, master_dtype=jnp.float32)
    compress_grads: bool = False     # int8 chunked compression before reduce
    compress_chunk: int = 2048


def init_opt_state(params, tcfg: TrainConfig):
    return optim.init(params, tcfg.adamw)


def make_train_step(cfg: lm.LMConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    The same function lowers on any mesh; batch leaves are (B_global, ...)
    with B_global % microbatches == 0.
    """

    def loss_fn(params, mbatch):
        loss, metrics = lm.forward(cfg, params, mbatch)
        return loss, metrics

    def train_step(params, opt_state, batch):
        mb = tcfg.microbatches
        if mb == 1:
            (loss, fwd_metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # microbatch split: constrain the per-microbatch batch dim back
            # onto the DP axes (the (B,) -> (mb, B/mb) reshape is not
            # sharding-preserving, and SPMD would otherwise replicate)
            split = jax.tree.map(
                lambda a: hint(
                    a.reshape((mb, a.shape[0] // mb) + a.shape[1:]),
                    None, "batch", *([None] * (a.ndim - 1))),
                batch)

            def body(carry, mbatch):
                acc, loss_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g)
                return (acc, loss_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0.0)), split)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss_sum / mb

        if tcfg.compress_grads:
            grads = jax.tree.map(
                lambda g: compress_lib.int8_roundtrip(g, tcfg.compress_chunk),
                grads)

        params, opt_state = optim.apply(params, grads, opt_state, tcfg.adamw)
        # fixed metrics structure (callers build out_shardings without tracing)
        metrics = {"loss": loss, "grad_norm": optim._global_norm(grads)}
        return params, opt_state, metrics

    return train_step


METRICS_KEYS = ("loss", "grad_norm")
