"""Fault-tolerant checkpointing: atomic, content-verified, async, elastic.

Layout (one directory per step):
    <dir>/step_00001230/
        manifest.json      — tree structure, per-leaf file/shape/dtype/crc,
                             step, wall time, mesh shape at save
        leaf_00000.npy ... — one file per pytree leaf
    <dir>/LATEST           — atomically-updated pointer file

Guarantees:
  - Atomicity: leaves are written to ``<dir>/.tmp_step_X`` and the directory
    is os.rename()d into place only after the manifest fsync — a crash
    mid-save never corrupts the previous checkpoint, and a crash mid-rename
    leaves a .tmp dir that is ignored and garbage-collected.
  - Integrity: each leaf carries a CRC32 in the manifest, verified on load.
  - Elasticity: leaves are saved UNSHARDED (gathered); ``restore`` re-shards
    onto whatever mesh/specs the restoring job provides — a checkpoint
    written on (16,16) restores onto (2,16,16), (4,8) or 1 device.  (On a
    real multi-host pod each host would gather only its addressable shards;
    single-controller here, noted in DESIGN.md.)
  - Async: ``save_async`` snapshots to host memory synchronously (cheap
    device->host copy) and does file I/O on a background thread, overlapping
    with the next training steps; ``wait()`` joins before the next save.
  - Retention: ``keep`` most recent checkpoints are retained.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _key_strings(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [jax.tree_util.keystr(p) for p, _ in paths]


def save(directory: str | os.PathLike, step: int, tree, *,
         extra: dict | None = None) -> Path:
    """Synchronous atomic checkpoint save.  Returns the final path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:010d}"
    tmp = directory / f".tmp_step_{step:010d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, _ = _flatten(tree)
    keys = _key_strings(tree)
    manifest = {"step": int(step), "time": time.time(),
                "extra": extra or {}, "leaves": []}
    for i, (key, leaf) in enumerate(zip(keys, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        # store raw bytes (uint8 view): np.save of ml_dtypes (bf16) arrays
        # does not round-trip without pickle; the manifest keeps truth
        np.save(tmp / fname, np.ascontiguousarray(arr).view(np.uint8
                                                            ).reshape(-1))
        manifest["leaves"].append({
            "key": key, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        })
    mpath = tmp / "manifest.json"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _update_latest(directory, final.name)
    return final


def _update_latest(directory: Path, name: str):
    ptr = directory / "LATEST"
    tmp = directory / ".LATEST.tmp"
    tmp.write_text(name)
    os.replace(tmp, ptr)


def latest_step(directory: str | os.PathLike) -> int | None:
    directory = Path(directory)
    ptr = directory / "LATEST"
    if ptr.exists():
        name = ptr.read_text().strip()
        if (directory / name / "manifest.json").exists():
            return int(name.split("_")[-1])
    # fall back to scanning (LATEST lost in a crash)
    steps = sorted(int(p.name.split("_")[-1])
                   for p in directory.glob("step_*")
                   if (p / "manifest.json").exists())
    return steps[-1] if steps else None


def restore(directory: str | os.PathLike, target_tree, *, step: int | None = None,
            shardings=None, verify: bool = True):
    """Restore into the structure of ``target_tree`` (shape/dtype checked).

    ``shardings``: optional pytree of NamedSharding — re-shard on load
    (elastic restart onto a different mesh).
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = directory / f"step_{step:010d}"
    manifest = json.loads((path / "manifest.json").read_text())

    leaves, treedef = _flatten(target_tree)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, target "
            f"expects {len(leaves)} — structure mismatch")
    shard_leaves = (_flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    out = []
    for meta, target, sh in zip(manifest["leaves"], leaves, shard_leaves):
        raw = np.load(path / meta["file"])
        arr = raw.view(np.dtype(meta["dtype"])).reshape(meta["shape"])
        if verify:
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != meta["crc32"]:
                raise IOError(f"CRC mismatch for {meta['key']} in {path}")
        if tuple(arr.shape) != tuple(target.shape):
            raise ValueError(f"shape mismatch for {meta['key']}: "
                             f"{arr.shape} vs {target.shape}")
        if sh is not None:
            out.append(jax.device_put(arr.astype(target.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr.astype(target.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def gc_tmp(directory: str | os.PathLike):
    """Remove orphaned .tmp dirs from crashed saves."""
    for p in Path(directory).glob(".tmp_step_*"):
        shutil.rmtree(p, ignore_errors=True)


class CheckpointManager:
    """keep-N retention + async background saves + resume."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3,
                 save_interval: int = 100):
        self.directory = Path(directory)
        self.keep = keep
        self.save_interval = save_interval
        self._thread: threading.Thread | None = None
        self.directory.mkdir(parents=True, exist_ok=True)
        gc_tmp(self.directory)

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval == 0

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree, extra: dict | None = None):
        """Snapshot to host memory now; write files on a background thread."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save(self.directory, step, host_tree, extra=extra)
            self._retain()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, step: int, tree, extra: dict | None = None):
        self.wait()
        save(self.directory, step, tree, extra=extra)
        self._retain()

    def _retain(self):
        steps = sorted(int(p.name.split("_")[-1])
                       for p in self.directory.glob("step_*"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:010d}",
                          ignore_errors=True)

    def restore_latest(self, target_tree, shardings=None):
        return restore(self.directory, target_tree, shardings=shardings)
