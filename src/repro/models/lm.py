"""Unified LM model zoo: one config + one param/spec/forward factory for all
ten assigned architectures.

Families
  decoder : llama3-405b, starcoder2-15b, deepseek-67b, stablelm-3b
  moe     : deepseek-moe-16b, moonshot-v1-16b-a3b (dense layer 0 + MoE rest)
  rwkv    : rwkv6-7b (attention-free; time-mix + channel-mix)
  hybrid  : hymba-1.5b (parallel GQA + Mamba heads, sliding window + globals)
  encdec  : whisper-medium (frame-embedding encoder + causal/cross decoder)
  vlm     : llama-3.2-vision-90b (decoder + gated cross-attn every 5th layer)

Conventions
  - Params are plain pytrees (dicts of jnp arrays); per-layer params are
    stacked with a leading layer axis and consumed by ``lax.scan`` (compact
    HLO at 126 layers, per-layer remat).
  - ``init(key, cfg, mesh_shape)`` returns ``(params, specs)`` — mirrored
    pytrees.  ``abstract=True`` returns ShapeDtypeStructs instead of arrays
    (no allocation — how the 405B dry-run builds its inputs).
  - Sharding: TP on "model", FSDP on "data", with automatic fallback to
    replication when a dim is not divisible by the mesh axis.
  - Modality frontends (whisper audio conv, VLM image tower) are STUBS per
    the assignment: batches carry precomputed frame/patch embeddings.
  - Serve caches: attention K/V are (L, B, Smax, Hkv, Dh); RWKV/Mamba carry
    O(1) recurrent state — which is why only those families run long_500k.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import hint
from repro.nn import attention, mlp as mlp_lib, norms, rope, ssm
from repro.nn.moe import MoEConfig, moe_ffn

DATA, MODEL = "data", "model"   # logical mesh axis names (pod handled by batch)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    family: str = "decoder"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    mlp_type: str = "swiglu"          # "swiglu" | "gelu"
    use_bias: bool = False            # whisper-style biases
    rope_theta: float = 500000.0
    pos_embedding: str = "rope"       # "rope" | "sinusoidal"
    norm_type: str = "rmsnorm"        # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_expert: int = 0
    first_dense_ff: int = 0           # layer-0 dense FFN width (moe family)
    moe_group_size: int = 2048
    moe_impl: str = "einsum"
    capacity_factor: float = 1.25
    # serving prefill routes dropless (see decoder_block): required for
    # prefix-cache resumption; off by default so the training forward and
    # the dry-run roofline cells keep GShard capacity semantics
    moe_dropless_prefill: bool = False
    # --- vlm ---
    cross_every: int = 0              # a cross-attn layer every k layers
    n_vision_tokens: int = 1024
    # --- encdec ---
    enc_layers: int = 0
    enc_len: int = 1500
    # --- hybrid / ssm ---
    ssm_state: int = 0
    d_inner: int = 0                  # mamba inner width (2*d_model default)
    dt_rank: int = 0
    conv_k: int = 4
    window: int = 0                   # sliding-window size (0 = full attn)
    global_every: int = 0             # every k-th layer is full attention
    # --- numerics / runtime ---
    param_dtype: str = "bfloat16"
    remat: str = "full"               # "none" | "full" | "dots"
    q_chunk: int = 512
    kv_chunk: int = 1024
    rwkv_chunk: int = 16
    ssm_chunk: int = 32
    loss_chunk: int = 1024            # vocab-projection sequence chunking
    # --- paper technique (SC frontend analogue; DESIGN §Arch-applicability)
    first_layer_mode: str = "none"    # "none" | "sc"
    sc_bits: int = 4
    # --- serving (beyond-paper): int8 KV cache with per-token-head scales
    kv_quant: bool = False

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def moe(self) -> MoEConfig | None:
        if self.n_experts == 0:
            return None
        return MoEConfig(self.n_experts, self.top_k, self.d_expert,
                         self.n_shared, self.capacity_factor,
                         self.moe_group_size, impl=self.moe_impl)

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 128) * 128

    @property
    def inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    def is_global_layer(self, idx):
        """Vector-friendly: full-attention layer predicate (hybrid family)."""
        if self.window == 0:
            return jnp.ones_like(idx, bool)
        if self.global_every == 0:
            return jnp.zeros_like(idx, bool)
        return (idx % self.global_every) == 0


_GLOBAL_WINDOW = 1 << 30   # "window" so large it never masks


def hybrid_grouped(cfg: "LMConfig") -> bool:
    """Whether the hybrid stack can use the grouped static-window layout."""
    return bool(cfg.window and cfg.global_every
                and cfg.n_layers % cfg.global_every == 0)


def layer_window(cfg: "LMConfig", idx):
    """Per-layer effective window: static 0 if the arch has no windowing,
    else a traced scalar (huge value on global-attention layers)."""
    if cfg.window == 0:
        return 0
    return jnp.where(cfg.is_global_layer(idx), _GLOBAL_WINDOW, cfg.window)


# ==========================================================================
# Param construction (+ mirrored spec tree; abstract mode for the dry-run).
# ==========================================================================

class _Builder:
    def __init__(self, key, cfg: LMConfig, mesh_shape: dict[str, int],
                 abstract: bool):
        self.key = key
        self.cfg = cfg
        self.mesh = mesh_shape or {}
        self.abstract = abstract

    def _split(self):
        if self.abstract:
            return None
        self.key, sub = jax.random.split(self.key)
        return sub

    def dense(self, shape, scale=None):
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, self.cfg.dtype)
        scale = scale if scale is not None else 1.0 / math.sqrt(shape[-2])
        return (scale * jax.random.truncated_normal(
            self._split(), -2, 2, shape, jnp.float32)).astype(self.cfg.dtype)

    def fill(self, shape, value):
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, self.cfg.dtype)
        return jnp.full(shape, value, self.cfg.dtype)

    def fn(self, shape, f):
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, self.cfg.dtype)
        return f().astype(self.cfg.dtype)

    def ok(self, size: int, axis: str) -> bool:
        return size % self.mesh.get(axis, 1) == 0

    def spec(self, shape, logical):
        out = []
        for dim, kind in zip(shape, logical):
            if kind == "tp" and self.ok(dim, MODEL):
                out.append(MODEL)
            elif kind == "fsdp" and self.ok(dim, DATA):
                out.append(DATA)
            else:
                out.append(None)
        return P(*out)


def _attn_params(b: _Builder, L, d, hq, hkv, dh, bias):
    lead = () if L is None else (L,)
    llog = () if L is None else (None,)
    p, s = {}, {}
    for nm, shape, logical in (
            ("wq", (d, hq * dh), ("fsdp", "tp")),
            ("wk", (d, hkv * dh), ("fsdp", "tp")),
            ("wv", (d, hkv * dh), ("fsdp", "tp")),
            ("wo", (hq * dh, d), ("tp", "fsdp"))):
        p[nm] = b.dense(lead + shape)
        s[nm] = b.spec(lead + shape, llog + logical)
    if bias:
        for nm, width, lg in (("bq", hq * dh, "tp"), ("bv", hkv * dh, "tp"),
                              ("bo", d, None)):
            p[nm] = b.fill(lead + (width,), 0.0)
            s[nm] = b.spec(lead + (width,), llog + (lg,))
    return p, s


def _mlp_params(b: _Builder, L, d, f, kind, bias):
    lead = () if L is None else (L,)
    llog = () if L is None else (None,)
    p, s = {}, {}
    names = (("w_gate", (d, f), ("fsdp", "tp")),
             ("w_in", (d, f), ("fsdp", "tp")),
             ("w_out", (f, d), ("tp", "fsdp"))) if kind == "swiglu" else \
            (("w_in", (d, f), ("fsdp", "tp")), ("w_out", (f, d), ("tp", "fsdp")))
    for nm, shape, logical in names:
        p[nm] = b.dense(lead + shape)
        s[nm] = b.spec(lead + shape, llog + logical)
    if bias and kind != "swiglu":
        p["b_in"] = b.fill(lead + (f,), 0.0)
        s["b_in"] = b.spec(lead + (f,), llog + ("tp",))
        p["b_out"] = b.fill(lead + (d,), 0.0)
        s["b_out"] = b.spec(lead + (d,), llog + (None,))
    return p, s


def _moe_params(b: _Builder, L, d):
    cfg = b.cfg
    m = cfg.moe
    f = m.d_expert
    p, s = {}, {}
    p["w_router"] = b.dense((L, d, m.n_experts))
    s["w_router"] = P(None, None, None)
    for nm, shape, logical in (
            ("w_gate", (L, m.n_experts, d, f), (None, "tp", "fsdp", None)),
            ("w_in", (L, m.n_experts, d, f), (None, "tp", "fsdp", None)),
            ("w_out", (L, m.n_experts, f, d), (None, "tp", None, "fsdp"))):
        p[nm] = b.dense(shape)
        s[nm] = b.spec(shape, logical)
    if m.n_shared:
        sf = m.n_shared * f
        for nm, shape, logical in (
                ("shared_gate", (L, d, sf), (None, "fsdp", "tp")),
                ("shared_in", (L, d, sf), (None, "fsdp", "tp")),
                ("shared_out", (L, sf, d), (None, "tp", "fsdp"))):
            p[nm] = b.dense(shape)
            s[nm] = b.spec(shape, logical)
    return p, s


def _norm_params(b: _Builder, L, d, bias=False):
    lead = () if L is None else (L,)
    p = {"scale": b.fill(lead + (d,), 1.0)}
    s = {"scale": P(*([None] * (len(lead) + 1)))}
    if bias:
        p["bias"] = b.fill(lead + (d,), 0.0)
        s["bias"] = P(*([None] * (len(lead) + 1)))
    return p, s


def _decoder_block_params(b: _Builder, L, *, moe_layer):
    cfg = b.cfg
    p, s = {}, {}
    p["ln1"], s["ln1"] = _norm_params(b, L, cfg.d_model, cfg.use_bias)
    p["attn"], s["attn"] = _attn_params(b, L, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.d_head,
                                        cfg.use_bias)
    p["ln2"], s["ln2"] = _norm_params(b, L, cfg.d_model, cfg.use_bias)
    if moe_layer:
        p["moe"], s["moe"] = _moe_params(b, L, cfg.d_model)
    else:
        ff = cfg.first_dense_ff if (cfg.family == "moe" and L == 1
                                    and cfg.first_dense_ff) else cfg.d_ff
        p["mlp"], s["mlp"] = _mlp_params(b, L, cfg.d_model, ff,
                                         cfg.mlp_type, cfg.use_bias)
    return p, s


def _cross_block_params(b: _Builder, L):
    """Gated cross-attention decoder block (VLM / whisper decoder)."""
    cfg = b.cfg
    p, s = _decoder_block_params(b, L, moe_layer=False)
    p["ln_x"], s["ln_x"] = _norm_params(b, L, cfg.d_model, cfg.use_bias)
    p["xattn"], s["xattn"] = _attn_params(b, L, cfg.d_model, cfg.n_heads,
                                          cfg.n_kv_heads, cfg.d_head,
                                          cfg.use_bias)
    p["gate_attn"] = b.fill((L,), 0.0 if cfg.family == "vlm" else 1.0)
    s["gate_attn"] = P(None)
    return p, s


def _rwkv_block_params(b: _Builder, L):
    cfg = b.cfg
    d = cfg.d_model
    hd = cfg.n_heads * cfg.d_head
    lora = 64
    p, s = {}, {}
    p["ln1"], s["ln1"] = _norm_params(b, L, d)
    p["ln2"], s["ln2"] = _norm_params(b, L, d)
    p["mu"] = b.fill((L, 7, d), 0.5)    # shift mixes: r,k,v,g,w + cm r,k
    s["mu"] = P(None, None, None)
    for nm in ("wr", "wk", "wv", "wg"):
        p[nm] = b.dense((L, d, hd))
        s[nm] = b.spec((L, d, hd), (None, "fsdp", "tp"))
    p["wo"] = b.dense((L, hd, d))
    s["wo"] = b.spec((L, hd, d), (None, "tp", "fsdp"))
    p["w0"] = b.fill((L, hd), -6.0)     # decay base (w = exp(-exp(.)))
    s["w0"] = P(None, None)
    p["w_lora_a"] = b.dense((L, d, lora))
    s["w_lora_a"] = P(None, None, None)
    p["w_lora_b"] = b.dense((L, lora, hd), scale=0.01)
    s["w_lora_b"] = b.spec((L, lora, hd), (None, None, "tp"))
    p["u"] = b.dense((L, cfg.n_heads, cfg.d_head), scale=0.3)
    s["u"] = P(None, None, None)
    p["ln_wkv"], s["ln_wkv"] = _norm_params(b, L, hd)
    p["cm_k"] = b.dense((L, d, cfg.d_ff))
    s["cm_k"] = b.spec((L, d, cfg.d_ff), (None, "fsdp", "tp"))
    p["cm_v"] = b.dense((L, cfg.d_ff, d))
    s["cm_v"] = b.spec((L, cfg.d_ff, d), (None, "tp", "fsdp"))
    p["cm_r"] = b.dense((L, d, d))
    s["cm_r"] = b.spec((L, d, d), (None, "fsdp", None))
    return p, s


def _hymba_block_params(b: _Builder, L):
    cfg = b.cfg
    d, di, N = cfg.d_model, cfg.inner, cfg.ssm_state
    dtr = cfg.dt_rank or max(16, d // 16)
    p, s = {}, {}
    p["ln1"], s["ln1"] = _norm_params(b, L, d)
    p["attn"], s["attn"] = _attn_params(b, L, d, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.d_head, False)
    p["in_proj"] = b.dense((L, d, 2 * di))
    s["in_proj"] = b.spec((L, d, 2 * di), (None, "fsdp", "tp"))
    p["conv_w"] = b.dense((L, cfg.conv_k, di), scale=0.5)
    s["conv_w"] = b.spec((L, cfg.conv_k, di), (None, None, "tp"))
    p["x_proj"] = b.dense((L, di, dtr + 2 * N))
    s["x_proj"] = b.spec((L, di, dtr + 2 * N), (None, "tp", None))
    p["dt_proj"] = b.dense((L, dtr, di))
    s["dt_proj"] = b.spec((L, dtr, di), (None, None, "tp"))
    p["dt_bias"] = b.fill((L, di), -4.6)
    s["dt_bias"] = b.spec((L, di), (None, "tp"))
    p["A_log"] = b.fn((L, di, N), lambda: jnp.broadcast_to(
        jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)), (L, di, N)))
    s["A_log"] = b.spec((L, di, N), (None, "tp", None))
    p["D_skip"] = b.fill((L, di), 1.0)
    s["D_skip"] = b.spec((L, di), (None, "tp"))
    p["ssm_out"] = b.dense((L, di, d))
    s["ssm_out"] = b.spec((L, di, d), (None, "tp", "fsdp"))
    p["norm_attn"], s["norm_attn"] = _norm_params(b, L, d)
    p["norm_ssm"], s["norm_ssm"] = _norm_params(b, L, d)
    p["beta"] = b.fill((L, 2), 1.0)
    s["beta"] = P(None, None)
    p["ln2"], s["ln2"] = _norm_params(b, L, d)
    p["mlp"], s["mlp"] = _mlp_params(b, L, d, cfg.d_ff, "swiglu", False)
    return p, s


def init(key, cfg: LMConfig, mesh_shape: dict[str, int] | None = None,
         abstract: bool = False) -> tuple[dict, dict]:
    """Returns (params, specs) — mirrored pytrees.  ``abstract=True`` builds
    ShapeDtypeStructs (no device memory; dry-run input)."""
    b = _Builder(key, cfg, mesh_shape or {}, abstract)
    d, V = cfg.d_model, cfg.vocab_padded
    p: dict = {}
    s: dict = {}
    # embed: vocab on TP only — FSDP on the gathered axis makes SPMD fall
    # back to a full rematerialization of the table (observed; see DESIGN.md)
    p["embed"] = b.dense((V, d), scale=0.02)
    s["embed"] = b.spec((V, d), ("tp", None))
    if not cfg.tie_embeddings:
        p["lm_head"] = b.dense((d, V))
        s["lm_head"] = b.spec((d, V), ("fsdp", "tp"))
    p["final_norm"], s["final_norm"] = _norm_params(b, None, d, cfg.use_bias)
    if cfg.first_layer_mode == "sc":
        # the paper's near-sensor SC first layer as an LM frontend projection
        p["sc_frontend"] = {"w": b.dense((d, d)),
                            "gamma": b.fill((d,), 1.0)}
        s["sc_frontend"] = {"w": b.spec((d, d), (None, None)),
                            "gamma": P(None)}

    fam = cfg.family
    if fam == "moe":
        p["dense0"], s["dense0"] = _decoder_block_params(b, 1, moe_layer=False)
        p["blocks"], s["blocks"] = _decoder_block_params(
            b, cfg.n_layers - 1, moe_layer=True)
    elif fam == "decoder":
        p["blocks"], s["blocks"] = _decoder_block_params(
            b, cfg.n_layers, moe_layer=False)
    elif fam == "rwkv":
        p["blocks"], s["blocks"] = _rwkv_block_params(b, cfg.n_layers)
    elif fam == "hybrid":
        p["blocks"], s["blocks"] = _hymba_block_params(b, cfg.n_layers)
    elif fam == "vlm":
        k = cfg.cross_every
        assert cfg.n_layers % k == 0
        n_groups = cfg.n_layers // k
        p["blocks"], s["blocks"] = _decoder_block_params(
            b, cfg.n_layers - n_groups, moe_layer=False)
        p["cross_blocks"], s["cross_blocks"] = _cross_block_params(b, n_groups)
    elif fam == "encdec":
        p["enc_blocks"], s["enc_blocks"] = _decoder_block_params(
            b, cfg.enc_layers, moe_layer=False)
        p["enc_norm"], s["enc_norm"] = _norm_params(b, None, d, cfg.use_bias)
        p["dec_blocks"], s["dec_blocks"] = _cross_block_params(b, cfg.n_layers)
    else:
        raise ValueError(fam)
    return p, s


def count_params(cfg: LMConfig) -> int:
    params, _ = init(None, cfg, abstract=True)
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def active_params(cfg: LMConfig) -> int:
    """Per-token active parameters (MoE: shared + top_k of routed)."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    routed = (cfg.n_layers - 1) * m.n_experts * 3 * cfg.d_model * m.d_expert
    active_routed = routed * m.top_k // m.n_experts
    return total - routed + active_routed


# ==========================================================================
# Blocks (forward).
# ==========================================================================

def _norm_apply(cfg, p, x):
    if cfg.norm_type == "layernorm" or "bias" in p:
        return norms.layernorm(x, p["scale"], p.get("bias", 0.0), cfg.norm_eps)
    return norms.rmsnorm(x, p["scale"], cfg.norm_eps)


def _proj(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w)
    return y if b is None else y + b


def _attn_apply(cfg: LMConfig, p, x, positions, *, causal=True, window=0,
                kv_override=None, q_offset=0, kv_prefix=None):
    """Full-sequence attention (train / prefill).  Returns (out, (k, v)).

    ``kv_prefix``: (k, v) of an already-computed (post-RoPE) cache prefix of
    ``q_offset`` positions — suffix-only chunked prefill.  Queries come from
    ``x`` (the suffix, at absolute positions given by ``positions``), keys
    concatenate prefix + suffix, and the returned ``(k, v)`` covers the full
    prefix+suffix length so the caller can assemble the whole cache.  The
    resumed path always attends through ``attend_chunked`` (sliding windows
    become masks): ``attend_sliding``'s tile slicing assumes queries and
    keys start at the same position, which a resumed call violates.

    Bit-exactness is a property of the *chunk schedule*, not of this
    function: chunk j of a block-aligned prefill fold has the same static
    shapes whether the fold started at 0 or resumed at a prefix hit, so XLA
    compiles the identical graph and the outputs match bitwise (see
    ``engine.prefill_chunked``).  A one-shot suffix call is mathematically
    identical to full prefill but may drift in the last ulp — differently
    shaped graphs fuse differently.
    """
    B, S, d = x.shape
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, S, cfg.n_heads, cfg.d_head)
    if kv_override is None:
        k = _proj(x, p["wk"]).reshape(B, -1, cfg.n_kv_heads, cfg.d_head)
        v = _proj(x, p["wv"], p.get("bv")).reshape(B, -1, cfg.n_kv_heads,
                                                   cfg.d_head)
        if cfg.pos_embedding == "rope":
            q = rope.apply_rope(q, positions, cfg.rope_theta)
            k = rope.apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
        if cfg.pos_embedding == "rope" and causal:
            q = rope.apply_rope(q, positions, cfg.rope_theta)
    if kv_prefix is not None:
        pk, pv = kv_prefix
        assert pk.shape[1] == q_offset, (pk.shape, q_offset)
        k_full = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v_full = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        if (isinstance(window, int) and 0 < window < q_offset and causal
                and kv_override is None):
            # a static-window layer only sees the trailing `window` prefix
            # positions — slice them (static shapes, so the fold's bitwise
            # resume property survives) to keep the O(S·window) bound the
            # one-shot path gets from attend_sliding.  Relative positions
            # are preserved by shifting q_offset with the slice.
            ka = jnp.concatenate(
                [pk[:, q_offset - window:].astype(k.dtype), k], axis=1)
            va = jnp.concatenate(
                [pv[:, q_offset - window:].astype(v.dtype), v], axis=1)
            o = attention.attend_chunked(q, ka, va, causal=True,
                                         window=window, q_offset=window,
                                         q_chunk=cfg.q_chunk,
                                         kv_chunk=cfg.kv_chunk)
        else:
            o = attention.attend_chunked(q, k_full, v_full, causal=causal,
                                         window=window, q_offset=q_offset,
                                         q_chunk=cfg.q_chunk,
                                         kv_chunk=cfg.kv_chunk)
        out = _proj(o.reshape(B, S, cfg.n_heads * cfg.d_head), p["wo"],
                    p.get("bo"))
        return out, (k_full, v_full)
    if (isinstance(window, int) and window > 0 and causal
            and kv_override is None and k.shape[1] == S):
        # static sliding window: true KV skipping (O(S*window) attention)
        o = attention.attend_sliding(q, k, v, window=window,
                                     q_offset=q_offset, q_chunk=cfg.q_chunk)
    else:
        o = attention.attend_chunked(q, k, v, causal=causal, window=window,
                                     q_offset=q_offset, q_chunk=cfg.q_chunk,
                                     kv_chunk=cfg.kv_chunk)
    out = _proj(o.reshape(B, S, cfg.n_heads * cfg.d_head), p["wo"],
                p.get("bo"))
    return out, (k, v)


def attn_decode_paged(cfg: LMConfig, p, x1, k_blocks, v_blocks, tables, pos,
                      *, window=0, kernel=None, interpret=None,
                      scales=None, backend=None, cascade=None):
    """One-token decode attention for a batch of slots, reading K/V in
    place from one layer's slice of the paged block arena.

    x1: (S, 1, d) normed activations (S = slot lanes); k_blocks, v_blocks:
    (num_blocks, 1, bs, Hkv, Dh) — one layer of ``engine.init_paged_arena``;
    tables: (S, nb) int32 arena block ids; pos: (S,) int32 per-lane lengths
    (the new token's row index).  ``window`` may be traced (per-layer
    sliding/global selection).  Returns (out (S, 1, d), k1, v1) with k1/v1
    the (S, Hkv, Dh) post-RoPE rows the caller scatters into the arena —
    the tick's only persistent sequence-axis write.

    The new token's row has not reached the arena yet when attention runs,
    so both paths overlay it at position ``pos`` functionally: the XLA
    reference (:func:`nn.attention.attend_decode_paged`) splices it into
    the gathered view — bitwise-identical to the dense
    ``engine.decode_step`` attention, which the paged parity suite pins —
    and ``kernel=True`` hands it to ``kernels.paged_attn`` as a row
    operand overlaid in VMEM (an arena-slice update here would copy every
    block of the layer, live or not — the very traffic the kernel's
    per-block DMA exists to avoid).

    ``scales``: optional (k_scale_blocks, v_scale_blocks) — one layer's
    slice of the int8 ``kv_quant`` scale arenas.  The new row is quantized
    post-RoPE (exactly :func:`engine._decode_attn`'s write) and attention
    reads the dequantized gathered view with the *dequantized-quantized*
    row spliced in — what the dense quant tick sees after its write — so
    in-place quant decode stays bitwise against the gather-tick oracle.
    Returns (out, k1q, v1q, k1_scale, v1_scale) in that case; the Pallas
    kernel path does not cover the quant layout (assert).

    ``backend`` ("xla" | "pallas" | "cascade", plus ``cascade=`` group
    metadata for the last — see :mod:`repro.serve.backend`) is the read-
    path dispatch forwarded to :func:`nn.attention.attend_decode_paged`;
    ``kernel=True`` survives as the deprecated alias for "pallas".
    """
    if backend is None:
        backend = "pallas" if kernel else "xla"
    B = x1.shape[0]
    q = _proj(x1, p["wq"], p.get("bq")).reshape(B, 1, cfg.n_heads, cfg.d_head)
    k1 = _proj(x1, p["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.d_head)
    v1 = _proj(x1, p["wv"], p.get("bv")).reshape(B, 1, cfg.n_kv_heads,
                                                 cfg.d_head)
    if cfg.pos_embedding == "rope":
        posb = pos[:, None]
        q = rope.apply_rope(q, posb, cfg.rope_theta)
        k1 = rope.apply_rope(k1, posb, cfg.rope_theta)
    kb, vb = k_blocks[:, 0], v_blocks[:, 0]      # (num_blocks, bs, Hkv, Dh)
    if scales is not None:
        assert backend == "xla", \
            "only the XLA reference covers the int8 kv_quant layout"
        from repro.serve import kvquant
        k1q, k1s = kvquant.quantize(k1)
        v1q, v1s = kvquant.quantize(v1)
        o = attention.attend_decode_paged(
            q, kb, vb, tables, pos + 1, window=window,
            new_kv=(kvquant.dequantize(k1q, k1s, cfg.dtype)[:, 0],
                    kvquant.dequantize(v1q, v1s, cfg.dtype)[:, 0]),
            scales=(scales[0][:, 0], scales[1][:, 0]), out_dtype=cfg.dtype)
        out = _proj(o.reshape(B, 1, cfg.n_heads * cfg.d_head), p["wo"],
                    p.get("bo"))
        return out, k1q[:, 0], v1q[:, 0], k1s[:, 0], v1s[:, 0]
    o = attention.attend_decode_paged(q, kb, vb, tables, pos + 1,
                                      window=window,
                                      new_kv=(k1[:, 0], v1[:, 0]),
                                      backend=backend, cascade=cascade,
                                      interpret=interpret)
    out = _proj(o.reshape(B, 1, cfg.n_heads * cfg.d_head), p["wo"],
                p.get("bo"))
    return out, k1[:, 0], v1[:, 0]


def _mlp_apply(cfg: LMConfig, p, x, kind=None):
    kind = kind or cfg.mlp_type
    if "w_gate" in p:
        return mlp_lib.swiglu(x, p["w_gate"], p["w_in"], p["w_out"])
    return mlp_lib.gelu_mlp(x, p["w_in"], p.get("b_in", 0.0), p["w_out"],
                            p.get("b_out", 0.0))


def decoder_block(cfg: LMConfig, p, x, positions, *, window=0, moe_layer=False,
                  q_offset=0, causal=True, kv_prefix=None, moe_dropless=False):
    """Pre-norm transformer block.  Returns (x, kv, aux).

    ``kv_prefix`` + ``q_offset`` resume from an existing KV prefix
    (suffix-only chunked prefill); ``kv`` then spans prefix + suffix.
    ``moe_dropless`` routes the MoE FFN with one whole-sequence dispatch
    group and never drops a token (serving prefill: a token's output must
    not depend on the rest of its dispatch group, or a prompt could not be
    resumed from a cached prefix — see ``cfg.moe_dropless_prefill``).
    """
    x = hint(x, "batch", None, None)
    h, kv = _attn_apply(cfg, p["attn"], _norm_apply(cfg, p["ln1"], x),
                        positions, causal=causal, window=window,
                        q_offset=q_offset, kv_prefix=kv_prefix)
    x = x + h
    z = _norm_apply(cfg, p["ln2"], x)
    if moe_layer:
        mcfg = cfg.moe
        if moe_dropless:
            mcfg = dataclasses.replace(
                mcfg, group_size=z.shape[0] * z.shape[1], dropless=True)
        y, aux = moe_ffn(z, p["moe"], mcfg)
    else:
        y, aux = _mlp_apply(cfg, p["mlp"], z), jnp.float32(0.0)
    return x + y, kv, aux


def cross_block(cfg: LMConfig, p, x, positions, enc_kv, *, q_offset=0,
                kv_prefix=None):
    """Self-attn + gated cross-attn + mlp (VLM cross layer, whisper decoder).

    ``kv_prefix`` resumes the causal self-attention from an existing KV
    prefix; the cross-attention needs no prefix (its K/V are the fixed
    encoder projections and each query row is independent of the others).
    """
    h, kv = _attn_apply(cfg, p["attn"], _norm_apply(cfg, p["ln1"], x),
                        positions, causal=True, q_offset=q_offset,
                        kv_prefix=kv_prefix)
    x = x + h
    hx, _ = _attn_apply(cfg, p["xattn"], _norm_apply(cfg, p["ln_x"], x),
                        positions, causal=False, kv_override=enc_kv)
    gate = jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(x.dtype)
    x = x + gate * hx
    y = _mlp_apply(cfg, p["mlp"], _norm_apply(cfg, p["ln2"], x))
    return x + y, kv


def _token_shift(x, last):
    """(B, S, d) shifted right by one; ``last`` (B, d) fills position 0."""
    return jnp.concatenate([last[:, None, :], x[:, :-1]], axis=1)


def rwkv_block(cfg: LMConfig, p, x, state):
    """RWKV6 block.  state: {"wkv": (B,H,D,D) f32, "shift1": (B,d),
    "shift2": (B,d)}.  Returns (x, new_state)."""
    B, S, d = x.shape
    H, Dh = cfg.n_heads, cfg.d_head
    xa = _norm_apply(cfg, p["ln1"], x)
    xs = _token_shift(xa, state["shift1"])
    mu = p["mu"]
    mix = lambda i: xa + (xs - xa) * mu[i]
    r = _proj(mix(0), p["wr"]).reshape(B, S, H, Dh)
    k = _proj(mix(1), p["wk"]).reshape(B, S, H, Dh)
    v = _proj(mix(2), p["wv"]).reshape(B, S, H, Dh)
    g = _proj(mix(3), p["wg"])
    ww = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsl,lh->bsh", jnp.einsum("bsd,dl->bsl", mix(4), p["w_lora_a"]),
        p["w_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww)).reshape(B, S, H, Dh)
    if S == 1:   # decode: O(1) recurrent step
        o1, wkv_state = ssm.wkv6_step(r[:, 0], k[:, 0], v[:, 0],
                                      w[:, 0].astype(x.dtype), p["u"],
                                      state["wkv"])
        wkv = o1[:, None].astype(x.dtype)
    else:
        wkv, wkv_state = ssm.wkv6_chunked(r, k, v, w.astype(x.dtype), p["u"],
                                          chunk=min(cfg.rwkv_chunk, S),
                                          state0=state["wkv"])
    wkv = norms.rmsnorm(wkv.reshape(B, S, H * Dh), p["ln_wkv"]["scale"],
                        cfg.norm_eps)
    att = _proj(wkv * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype),
                p["wo"])
    x = x + att
    xc = _norm_apply(cfg, p["ln2"], x)
    xcs = _token_shift(xc, state["shift2"])
    kr = xc + (xcs - xc) * mu[5]
    rr = xc + (xcs - xc) * mu[6]
    kk = jnp.square(jax.nn.relu(_proj(kr, p["cm_k"]).astype(jnp.float32))
                    ).astype(x.dtype)
    cm = jax.nn.sigmoid(_proj(rr, p["cm_r"]).astype(jnp.float32)
                        ).astype(x.dtype) * _proj(kk, p["cm_v"])
    x = x + cm
    new_state = {"wkv": wkv_state, "shift1": xa[:, -1], "shift2": xc[:, -1]}
    return x, new_state


def _causal_conv(x, w, prev):
    """Depthwise causal conv: x (B,S,di), w (K,di), prev (B,K-1,di)."""
    K = w.shape[0]
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out, xp[:, -(K - 1):]


def hymba_block(cfg: LMConfig, p, x, positions, state, *, window, q_offset=0,
                kv_prefix=None):
    """Parallel GQA + Mamba block.  state: {"conv": (B,K-1,di),
    "ssm": (B,di,N) f32}.  Returns (x, kv, new_state).

    ``state`` is the recurrent boundary condition: fresh zeros for a
    from-scratch prefill, or the conv taps / SSM state at position
    ``q_offset`` when resuming with ``kv_prefix`` (chunked prefill)."""
    B, S, d = x.shape
    z = _norm_apply(cfg, p["ln1"], x)
    att, kv = _attn_apply(cfg, p["attn"], z, positions, causal=True,
                          window=window, q_offset=q_offset,
                          kv_prefix=kv_prefix)
    xz = _proj(z, p["in_proj"])
    xm, gate = jnp.split(xz, 2, axis=-1)
    xm, conv_state = _causal_conv(xm, p["conv_w"], state["conv"])
    xm = jax.nn.silu(xm.astype(jnp.float32)).astype(x.dtype)
    dtr = p["dt_proj"].shape[0]
    dbc = _proj(xm, p["x_proj"])
    dt = jax.nn.softplus(
        _proj(dbc[..., :dtr], p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    N = cfg.ssm_state
    Bm, Cm = dbc[..., dtr:dtr + N], dbc[..., dtr + N:]
    y, ssm_state = ssm.selective_scan(xm, dt.astype(x.dtype), p["A_log"],
                                      Bm, Cm, p["D_skip"],
                                      chunk=min(cfg.ssm_chunk, S),
                                      state0=state["ssm"])
    y = y * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    y = _proj(y, p["ssm_out"])
    beta = p["beta"].astype(jnp.float32)
    mixed = (beta[0] * _norm_apply(cfg, p["norm_attn"], att).astype(jnp.float32)
             + beta[1] * _norm_apply(cfg, p["norm_ssm"], y).astype(jnp.float32)
             ) * 0.5
    x = x + mixed.astype(x.dtype)
    x = x + _mlp_apply(cfg, p["mlp"], _norm_apply(cfg, p["ln2"], x))
    return x, kv, {"conv": conv_state, "ssm": ssm_state}


# ==========================================================================
# Whole-model forward (train) — scan over layers + remat.
# ==========================================================================

def _maybe_remat(cfg, f):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(f)


def _sinusoidal(S, d, offset=0):
    pos = jnp.arange(offset, offset + S, dtype=jnp.float32)[:, None]
    i = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, i / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe


def sc_frontend(cfg: LMConfig, p, x):
    """The paper's technique as an LM frontend (DESIGN §Arch-applicability):
    the first projection runs in the simulated stochastic domain — split
    pos/neg unipolar weights, TFF adder tree, sign activation — with a
    straight-through estimator so the binary remainder retrains around it
    (exactly the paper's recovery mechanism).

    Functional-sim cost is O(d) table gathers per output; intended for the
    near-sensor-scale modality frontends and smoke configs — the dry-run
    roofline cells keep it off (see DESIGN §5).
    """
    from repro.core import sc_layer
    B, S, d = x.shape
    # sensor normalization: map activations into [0, 1] per feature vector
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    x01 = ((x - lo) / jnp.maximum(hi - lo, 1e-6)).astype(jnp.float32)
    w = p["w"].astype(jnp.float32)
    sc_cfg = sc_layer.SCConfig(bits=cfg.sc_bits)
    sc_out = sc_layer.sc_dot_sign(x01, w, sc_cfg)          # {-1, 0, 1}
    # straight-through: forward = SC sim, backward = the linear surrogate
    lin = jnp.einsum("bsd,df->bsf", x01, w)
    out = jax.lax.stop_gradient(sc_out - lin) + lin
    return (out * p["gamma"].astype(jnp.float32)).astype(x.dtype)


def embed_tokens(cfg: LMConfig, params, tokens, pos_offset: int = 0):
    """``pos_offset``: absolute position of tokens[0] (suffix-only prefill
    embeds its tokens at their true positions, not from 0)."""
    x = params["embed"][tokens]
    if cfg.pos_embedding == "sinusoidal":
        x = x + _sinusoidal(tokens.shape[1], cfg.d_model,
                            offset=pos_offset).astype(x.dtype)[None]
    if cfg.first_layer_mode == "sc":
        x = x + sc_frontend(cfg, params["sc_frontend"], x)   # residual insert
    return hint(x, "batch", None, None)


def _stack_scan(cfg, params_stacked, body, x, xs_extra=None):
    """Scan ``body`` over the leading layer axis of ``params_stacked``.

    body(layer_params, x, extra) -> (x, per_layer_output)
    """
    L = jax.tree.leaves(params_stacked)[0].shape[0]
    wrapped = _maybe_remat(cfg, body)

    def scan_fn(carry, inp):
        lp, extra = inp
        return wrapped(lp, carry, extra)

    xs = (params_stacked,
          xs_extra if xs_extra is not None else jnp.zeros((L,), jnp.int32))
    return jax.lax.scan(scan_fn, x, xs)


def forward(cfg: LMConfig, params, batch) -> tuple[jax.Array, dict]:
    """Training forward: next-token cross-entropy.

    batch: {"tokens": (B,S) i32, "labels": (B,S) i32 (-1 = ignore),
            optional "enc_embed": (B,Tenc,d), "vision_embed": (B,Tv,d)}.
    Returns (loss, metrics).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux_total = jnp.float32(0.0)
    fam = cfg.family

    if fam in ("decoder", "moe"):
        if fam == "moe":
            p0 = jax.tree.map(lambda a: a[0], params["dense0"])
            x, _, _ = decoder_block(cfg, p0, x, positions)

        def body(lp, x, idx):
            w = layer_window(cfg, idx)
            x, _, aux = decoder_block(cfg, lp, x, positions,
                                      window=w, moe_layer=(fam == "moe"))
            return x, aux
        L = cfg.n_layers - (1 if fam == "moe" else 0)
        x, auxs = _stack_scan(cfg, params["blocks"], body, x,
                              jnp.arange(L, dtype=jnp.int32))
        aux_total = jnp.sum(auxs)

    elif fam == "rwkv":
        def body(lp, x, _):
            state = {"wkv": jnp.zeros((B, cfg.n_heads, cfg.d_head, cfg.d_head),
                                      jnp.float32),
                     "shift1": jnp.zeros((B, cfg.d_model), x.dtype),
                     "shift2": jnp.zeros((B, cfg.d_model), x.dtype)}
            x, _ = rwkv_block(cfg, lp, x, state)
            return x, jnp.float32(0.0)
        x, _ = _stack_scan(cfg, params["blocks"], body, x)

    elif fam == "hybrid":
        def fresh_state():
            return {"conv": jnp.zeros((B, cfg.conv_k - 1, cfg.inner),
                                      x.dtype),
                    "ssm": jnp.zeros((B, cfg.inner, cfg.ssm_state),
                                     jnp.float32)}

        if hybrid_grouped(cfg):
            # [1 global + (g-1) sliding] x G groups with STATIC windows, so
            # sliding layers get true KV skipping (attend_sliding)
            G, ge = cfg.n_layers // cfg.global_every, cfg.global_every
            grouped = jax.tree.map(
                lambda a: a.reshape((G, ge) + a.shape[1:]), params["blocks"])

            def group_body(gp, x, _):
                g0 = jax.tree.map(lambda a: a[0], gp)
                rest = jax.tree.map(lambda a: a[1:], gp)
                x, _, _ = hymba_block(cfg, g0, x, positions, fresh_state(),
                                      window=0)

                def inner(lp, x, __):
                    x, _, _ = hymba_block(cfg, lp, x, positions,
                                          fresh_state(), window=cfg.window)
                    return x, jnp.float32(0.0)
                x, _ = _stack_scan(cfg, rest, inner, x)
                return x, jnp.float32(0.0)

            def outer(carry, gp):
                return _maybe_remat(cfg, group_body)(gp, carry, None)
            x, _ = jax.lax.scan(outer, x, grouped)
        else:
            def body(lp, x, idx):
                x, _, _ = hymba_block(cfg, lp, x, positions, fresh_state(),
                                      window=layer_window(cfg, idx))
                return x, jnp.float32(0.0)
            x, _ = _stack_scan(cfg, params["blocks"], body, x,
                               jnp.arange(cfg.n_layers, dtype=jnp.int32))

    elif fam == "vlm":
        vis = batch["vision_embed"].astype(x.dtype)
        enc_kv = None  # per-cross-layer KV computed from vis inside the block
        k = cfg.cross_every
        n_groups = cfg.n_layers // k
        self_pp = jax.tree.map(
            lambda a: a.reshape((n_groups, k - 1) + a.shape[1:]),
            params["blocks"])

        def group_body(gp, x, _):
            self_p, cross_p = gp

            def inner(lp, x, __):
                x, _, _ = decoder_block(cfg, lp, x, positions)
                return x, jnp.float32(0.0)
            x, _ = _stack_scan(cfg, self_p, inner, x)
            kx = _proj(vis, cross_p["xattn"]["wk"]).reshape(
                B, -1, cfg.n_kv_heads, cfg.d_head)
            vx = _proj(vis, cross_p["xattn"]["wv"]).reshape(
                B, -1, cfg.n_kv_heads, cfg.d_head)
            x, _ = cross_block(cfg, cross_p, x, positions, (kx, vx))
            return x, jnp.float32(0.0)

        def outer(carry, inp):
            return _maybe_remat(cfg, group_body)(inp, carry, None)
        x, _ = jax.lax.scan(outer, x, (self_pp, params["cross_blocks"]))

    elif fam == "encdec":
        enc = batch["enc_embed"].astype(x.dtype)
        enc = enc + _sinusoidal(enc.shape[1], cfg.d_model
                                ).astype(enc.dtype)[None]
        enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1]),
                                   (B, enc.shape[1]))

        def enc_body(lp, h, _):
            h, _, _ = decoder_block(cfg, lp, h, enc_pos, causal=False)
            return h, jnp.float32(0.0)
        enc, _ = _stack_scan(cfg, params["enc_blocks"], enc_body, enc)
        enc = _norm_apply(cfg, params["enc_norm"], enc)

        def dec_body(lp, x, _):
            kx = _proj(enc, lp["xattn"]["wk"]).reshape(
                B, -1, cfg.n_kv_heads, cfg.d_head)
            vx = _proj(enc, lp["xattn"]["wv"], lp["xattn"].get("bv")).reshape(
                B, -1, cfg.n_kv_heads, cfg.d_head)
            x, _ = cross_block(cfg, lp, x, positions, (kx, vx))
            return x, jnp.float32(0.0)
        x, _ = _stack_scan(cfg, params["dec_blocks"], dec_body, x)
    else:
        raise ValueError(fam)

    x = _norm_apply(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    loss, n_tok = chunked_xent(cfg, x, head, batch["labels"])
    total = loss + 0.01 * aux_total
    return total, {"loss": loss, "aux": aux_total, "tokens": n_tok}


def moe_ffn_decode(cfg: LMConfig, moe_params, z):
    """MoE FFN for a (B, 1, d) decode activation: a single dispatch group of
    B tokens (capacity stays tiny at decode batch sizes)."""
    B = z.shape[0]
    m = dataclasses.replace(cfg.moe, group_size=B)
    return moe_ffn(z.reshape(1, B, -1), moe_params, m)[0].reshape(B, 1, -1), \
        jnp.float32(0.0)


def chunked_xent(cfg: LMConfig, x, head, labels):
    """Cross-entropy with the vocab projection chunked over sequence (the
    (S, V) logits for a 128k vocab never materialize at full length)."""
    B, S, d = x.shape
    ck = min(cfg.loss_chunk, S)
    nc = -(-S // ck)
    xp = jnp.pad(x, ((0, 0), (0, nc * ck - S), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, nc * ck - S)), constant_values=-1)
    xc = xp.reshape(B, nc, ck, d).swapaxes(0, 1)
    lc = lp.reshape(B, nc, ck).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(xi, li):
        logits = hint(jnp.einsum("bsd,dv->bsv", xi, head,
                                 preferred_element_type=jnp.float32),
                      "batch", None, "model")
        mask = li >= 0
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(li, 0)[..., None],
                                 axis=-1)[..., 0]
        return jnp.sum((lse - ll) * mask), jnp.sum(mask)

    def body(carry, inp):
        tot, cnt = carry
        l, n = chunk_loss(*inp)
        return (tot + l, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (xc, lc))
    return tot / jnp.maximum(cnt, 1.0), cnt
