"""LeNet-5 (Keras-library variant, paper Fig. 3) in pure JAX.

Topology: conv 32@5x5 (SAME) -> maxpool 2x2 -> conv 64@5x5 (SAME) ->
maxpool 2x2 -> dense 512 -> dropout 0.5 -> dense 10.

The first layer is swappable between three modes (the paper's three designs):
  "float"  — fp32 conv + ReLU (the pretrained base model)
  "binary" — k-bit quantized weights + sign activation (Table 3 'Binary')
  "sc"     — the stochastic-domain layer of §IV (Table 3 'This Work'/'Old SC')
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import sc_layer
from repro.core.sc_layer import SCConfig


@dataclasses.dataclass(frozen=True)
class LeNetConfig:
    image_size: int = 28
    channels: int = 1
    conv1_filters: int = 32
    conv2_filters: int = 64
    ksize: int = 5
    dense: int = 512
    classes: int = 10
    dropout: float = 0.5


def init(key: jax.Array, cfg: LeNetConfig = LeNetConfig()) -> dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ks, c1, c2 = cfg.ksize, cfg.conv1_filters, cfg.conv2_filters
    flat = (cfg.image_size // 4) * (cfg.image_size // 4) * c2
    he = jax.nn.initializers.he_normal()
    return {
        "conv1": {"w": he(k1, (ks, ks, cfg.channels, c1), jnp.float32),
                  "b": jnp.zeros((c1,))},
        "conv2": {"w": he(k2, (ks, ks, c1, c2), jnp.float32),
                  "b": jnp.zeros((c2,))},
        "dense1": {"w": he(k3, (flat, cfg.dense), jnp.float32),
                   "b": jnp.zeros((cfg.dense,))},
        "dense2": {"w": he(k4, (cfg.dense, cfg.classes), jnp.float32),
                   "b": jnp.zeros((cfg.classes,))},
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _maxpool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def first_layer(params, x, mode: str = "float", sc_cfg: SCConfig | None = None,
                bits: int = 8, soft_threshold: float = 0.0,
                sc_impl: str = "table") -> jax.Array:
    """First-layer feature maps (B, 28, 28, conv1_filters).

    x: (B, H, W, C) in [0, 1] (8-bit sensor data scaled).
    The quantized/stochastic modes have no bias term — the activation is
    ``sign(x ∘ w)`` exactly as in the paper's Fig. 3 engine.
    """
    w = params["conv1"]["w"]
    if mode == "float":
        return jax.nn.relu(_conv(x, w, params["conv1"]["b"]))
    if mode == "binary":
        return sc_layer.binary_conv2d_sign(x, w, bits, soft_threshold)
    if mode == "sc":
        assert sc_cfg is not None
        return sc_layer.sc_conv2d_sign(x, w, sc_cfg, impl=sc_impl)
    raise ValueError(f"unknown first-layer mode {mode}")


def tail(params, h1, cfg: LeNetConfig = LeNetConfig(), *,
         train: bool = False, dropout_key: jax.Array | None = None) -> jax.Array:
    """Everything after the first layer — the binary-domain remainder that the
    paper retrains.  h1: (B, 28, 28, conv1_filters)."""
    h = _maxpool(h1)
    h = jax.nn.relu(_conv(h, params["conv2"]["w"], params["conv2"]["b"]))
    h = _maxpool(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["dense1"]["w"] + params["dense1"]["b"])
    if train and cfg.dropout > 0:
        keep = 1.0 - cfg.dropout
        mask = jax.random.bernoulli(dropout_key, keep, h.shape)
        h = jnp.where(mask, h / keep, 0.0)
    return h @ params["dense2"]["w"] + params["dense2"]["b"]


def apply(params, x, cfg: LeNetConfig = LeNetConfig(), *, mode: str = "float",
          sc_cfg: SCConfig | None = None, bits: int = 8,
          soft_threshold: float = 0.0, train: bool = False,
          dropout_key: jax.Array | None = None, sc_impl: str = "table"
          ) -> jax.Array:
    h1 = first_layer(params, x, mode, sc_cfg, bits, soft_threshold, sc_impl)
    if mode != "float":
        h1 = jax.lax.stop_gradient(h1)   # frozen stochastic/quantized front
    return tail(params, h1, cfg, train=train, dropout_key=dropout_key)
