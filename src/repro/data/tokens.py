"""Deterministic synthetic token pipeline (offline LM pretraining stand-in).

Stateless ``(seed, step) -> batch`` map: any host can recompute any batch,
which is the property that makes straggler recovery, elastic restart and
data-parallel resharding trivial (no iterator state in checkpoints — just
the step counter).

Sequences are a learnable mixture: a random affine-recurrence "grammar"
(token_{t+1} ≈ a·token_t + b mod V with noise) over a per-sequence regime,
so small models show decreasing loss in the examples.
"""
from __future__ import annotations

import numpy as np


def batch_at(seed: int, step: int, batch: int, seq: int, vocab: int,
             noise: float = 0.1):
    """Returns {"tokens": (B,S) int32, "labels": (B,S) int32}.

    labels[t] = tokens[t+1] (next-token prediction), last label ignored (-1).
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    a = rng.integers(1, 17, size=(batch, 1))
    b = rng.integers(0, vocab, size=(batch, 1))
    t0 = rng.integers(0, vocab, size=(batch, 1))
    idx = np.arange(seq)[None, :]
    toks = (t0 + a * idx + b * (idx // 7)) % vocab
    flip = rng.random((batch, seq)) < noise
    toks = np.where(flip, rng.integers(0, vocab, size=(batch, seq)), toks)
    toks = toks.astype(np.int32)
    labels = np.concatenate(
        [toks[:, 1:], np.full((batch, 1), -1, np.int32)], axis=1)
    return {"tokens": toks, "labels": labels}


class TokenPipeline:
    """Iterator facade over the stateless map (keeps the step counter only)."""

    def __init__(self, seed: int, batch: int, seq: int, vocab: int,
                 start_step: int = 0):
        self.seed, self.batch, self.seq, self.vocab = seed, batch, seq, vocab
        self.step = start_step

    def next(self):
        out = batch_at(self.seed, self.step, self.batch, self.seq, self.vocab)
        self.step += 1
        return out
