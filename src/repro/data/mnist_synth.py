"""Procedural synthetic handwritten-digit dataset (offline MNIST stand-in).

The container has no network access, so we generate a deterministic 28x28
8-bit greyscale digit dataset with the same shape/dtype/label contract as
MNIST.  Digits are rendered from polyline stroke skeletons with random affine
jitter (shift/rotate/scale), stroke thickness, blur, and sensor noise.

Absolute accuracies on this set differ from the paper's MNIST numbers; the
claims we validate (EXPERIMENTS.md) are the *relative* ones — hybrid-vs-binary
accuracy gap after retraining, adder ordering, the 2-bit collapse — which are
properties of the arithmetic, not the dataset.  This substitution is recorded
per-experiment.
"""
from __future__ import annotations

import functools

import numpy as np

# Stroke skeletons on a [0,1]^2 canvas (x right, y down), per digit.
_STROKES: dict[int, list[list[tuple[float, float]]]] = {
    0: [[(0.5, 0.12), (0.76, 0.3), (0.76, 0.7), (0.5, 0.88), (0.24, 0.7),
         (0.24, 0.3), (0.5, 0.12)]],
    1: [[(0.35, 0.3), (0.55, 0.12), (0.55, 0.88)], [(0.35, 0.88), (0.75, 0.88)]],
    2: [[(0.25, 0.3), (0.45, 0.12), (0.7, 0.22), (0.72, 0.45), (0.25, 0.88),
         (0.78, 0.88)]],
    3: [[(0.25, 0.18), (0.7, 0.18), (0.45, 0.45), (0.72, 0.62), (0.6, 0.85),
         (0.25, 0.82)]],
    4: [[(0.62, 0.88), (0.62, 0.12), (0.22, 0.62), (0.8, 0.62)]],
    5: [[(0.72, 0.12), (0.3, 0.12), (0.28, 0.48), (0.6, 0.45), (0.74, 0.68),
         (0.55, 0.88), (0.25, 0.8)]],
    6: [[(0.65, 0.12), (0.35, 0.4), (0.27, 0.7), (0.5, 0.88), (0.7, 0.72),
         (0.62, 0.5), (0.3, 0.55)]],
    7: [[(0.22, 0.12), (0.78, 0.12), (0.45, 0.88)], [(0.35, 0.5), (0.65, 0.5)]],
    8: [[(0.5, 0.12), (0.72, 0.28), (0.5, 0.48), (0.28, 0.28), (0.5, 0.12)],
        [(0.5, 0.48), (0.75, 0.68), (0.5, 0.88), (0.25, 0.68), (0.5, 0.48)]],
    9: [[(0.7, 0.45), (0.4, 0.5), (0.3, 0.28), (0.55, 0.12), (0.72, 0.3),
         (0.68, 0.65), (0.45, 0.88)]],
}


def _render(digit: int, rng: np.random.Generator, size: int = 28) -> np.ndarray:
    """Rasterize one digit with random affine jitter and noise -> uint8 (28,28)."""
    canvas = np.zeros((size, size), dtype=np.float32)
    angle = rng.uniform(-0.26, 0.26)               # ±15°
    scale = rng.uniform(0.8, 1.15)
    dx, dy = rng.uniform(-0.1, 0.1, size=2)
    ca, sa = np.cos(angle), np.sin(angle)
    thick = rng.uniform(0.9, 1.7)
    for stroke in _STROKES[digit]:
        pts = np.asarray(stroke, dtype=np.float32)
        # jitter control points slightly for handwriting variance
        pts = pts + rng.normal(0, 0.02, pts.shape).astype(np.float32)
        # affine about canvas center
        c = pts - 0.5
        pts = np.stack([ca * c[:, 0] - sa * c[:, 1] + 0.5 + dx,
                        sa * c[:, 0] + ca * c[:, 1] + 0.5 + dy], axis=1) * scale \
            + (1 - scale) * 0.5
        # draw segments with dense sampling
        for p0, p1 in zip(pts[:-1], pts[1:]):
            n = max(2, int(np.hypot(*(p1 - p0)) * size * 3))
            ts = np.linspace(0, 1, n)[:, None]
            xy = p0[None] * (1 - ts) + p1[None] * ts
            px = np.clip((xy * size).astype(np.int32), 0, size - 1)
            canvas[px[:, 1], px[:, 0]] = 1.0
    # thickness via box blur iterations
    k = int(round(thick))
    for _ in range(max(1, k)):
        canvas = np.maximum(canvas, 0.6 * (
            np.roll(canvas, 1, 0) + np.roll(canvas, -1, 0)
            + np.roll(canvas, 1, 1) + np.roll(canvas, -1, 1)) / 2)
    canvas = np.clip(canvas, 0, 1)
    # soft blur
    blur = (canvas
            + np.roll(canvas, 1, 0) + np.roll(canvas, -1, 0)
            + np.roll(canvas, 1, 1) + np.roll(canvas, -1, 1)) / 5.0
    img = 255 * (0.85 * blur + 0.15 * canvas)
    img += rng.normal(0, 6, img.shape)             # sensor noise
    return np.clip(img, 0, 255).astype(np.uint8)


@functools.lru_cache(maxsize=4)
def dataset(n_train: int = 8000, n_test: int = 2000, seed: int = 0
            ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Deterministic dataset: (x_train u8 (n,28,28,1), y_train, x_test, y_test)."""
    rng = np.random.default_rng(seed)
    n = n_train + n_test
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    imgs = np.stack([_render(int(d), rng) for d in labels])[..., None]
    return (imgs[:n_train], labels[:n_train], imgs[n_train:], labels[n_train:])


def batches(x: np.ndarray, y: np.ndarray, batch: int, seed: int, steps: int):
    """Deterministic stateless batch iterator: any (seed, step) is recomputable,
    which is what makes straggler recovery / elastic restart trivial."""
    n = x.shape[0]
    for step in range(steps):
        rng = np.random.default_rng((seed, step))
        idx = rng.integers(0, n, size=batch)
        yield x[idx].astype(np.float32) / 255.0, y[idx]
