"""GPipe pipeline parallelism over a "stage" mesh axis.

Each device holds one stage's params (leading axis sharded over ``stage``);
microbatches stream through the pipeline with activations handed to the
next stage by ``ppermute`` (point-to-point, lowering to collective-permute
— no all-gather of activations).  The schedule runs
``n_micro + n_stages - 1`` ticks: stage 0 injects microbatch ``t`` at tick
``t``, the last stage emits microbatch ``t - (n_stages - 1)``, and a final
``psum`` replicates the collected outputs (all other stages contribute
zeros).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def gpipe_apply(stage_fn, params, xs, mesh, axis: str = "stage"):
    """Apply ``n_stages`` chained ``stage_fn`` s to each microbatch.

    stage_fn : (stage_params, x) -> y with y.shape == x.shape
    params   : pytree with a leading (n_stages, ...) axis on every leaf
    xs       : (n_micro, B, ...) microbatch stream
    Returns (n_micro, B, ...) outputs, replicated across the mesh.
    """
    n_stages = mesh.shape[axis]
    n_micro = xs.shape[0]
    ticks = n_micro + n_stages - 1

    def per_device(p_local, xs_local):
        p = jax.tree.map(lambda a: a[0], p_local)     # this stage's params
        stage = jax.lax.axis_index(axis)
        state = jnp.zeros_like(xs_local[0])
        outs = jnp.zeros_like(xs_local)

        def tick(t, carry):
            state, outs = carry
            inject = xs_local[jnp.minimum(t, n_micro - 1)]
            state = jnp.where(stage == 0, inject, state)
            y = stage_fn(p, state)
            m = t - (n_stages - 1)
            outs = jax.lax.cond(
                jnp.logical_and(stage == n_stages - 1, m >= 0),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(m, 0), 0),
                lambda o: o, outs)
            y = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return y, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (state, outs))
        return jax.lax.psum(outs, axis)    # replicate (others hold zeros)

    pspec = jax.tree.map(
        lambda a: P(*((axis,) + (None,) * (a.ndim - 1))), params)
    xspec = P(*((None,) * xs.ndim))
    return shard_map(
        per_device, mesh=mesh,
        in_specs=(pspec, xspec), out_specs=xspec,
        check_rep=False)(params, xs)
