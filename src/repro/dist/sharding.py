"""Mesh-axis conventions + sharding helpers shared by init/train/serve.

Convention (see launch.mesh): the innermost mesh axis ``"model"`` carries
tensor/expert parallelism; every other axis (``"pod"``, ``"data"``, ...) is
data parallel.  Specs are *functions of the mesh*, never baked into params —
that is what makes elastic restarts (same checkpoint, different --mesh)
work.

Two families of helpers live here:

  spec construction — ``batch_spec_axis`` / ``axis_if_divisible`` pick mesh
    axes only when the dim divides evenly (falling back to replication, never
    erroring on odd sizes); ``zero_shard_specs`` adds the ZeRO-1 rule: shard
    each optimizer-state leaf's largest *free* dim across the DP axes
    (``zero_shard_rule``), so moments/master weights cost 1/dp_size per chip.

  activation hints — ``hint(x, "batch", None, "model")`` places a
    ``with_sharding_constraint`` when an activation mesh is active
    (``use_activation_mesh``) and is an exact no-op otherwise, so model code
    is mesh-agnostic and single-device tests never touch device state.
"""
from __future__ import annotations

import contextlib
import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"


# ==========================================================================
# Mesh-shape utilities.
# ==========================================================================

def mesh_shape_dict(mesh) -> dict[str, int]:
    """{axis_name: size} for a jax Mesh (insertion order = mesh order)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh_shape: dict[str, int]) -> tuple[str, ...]:
    """All non-"model" axes, outermost first (the data-parallel group)."""
    return tuple(a for a in mesh_shape if a != MODEL_AXIS)


def dp_size(mesh_shape: dict[str, int]) -> int:
    return math.prod(mesh_shape[a] for a in dp_axes(mesh_shape)) or 1


def axis_if_divisible(axis: str, size: int, mesh_shape: dict[str, int]):
    """``axis`` when ``size`` divides evenly over it, else None (replicate)."""
    return axis if size % mesh_shape.get(axis, 1) == 0 else None


def batch_spec_axis(mesh_shape: dict[str, int], batch: int):
    """DP axes to shard a batch dim over: the longest suffix-aligned group
    of DP axes whose product divides ``batch`` (single axis collapses to its
    bare name, so ``P(batch_spec_axis(...), None)`` reads naturally)."""
    axes = dp_axes(mesh_shape)
    for i in range(len(axes)):
        cand = axes[i:]
        size = math.prod(mesh_shape[a] for a in cand)
        if size > 1 and batch % size == 0:
            return cand[0] if len(cand) == 1 else cand
    return None


def named(mesh, tree):
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``."""
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), tree,
                        is_leaf=lambda x: isinstance(x, P))


def slice_meshes(mesh) -> list:
    """Factor a serving mesh into one sub-mesh per data-parallel coordinate.

    The innermost ``"model"`` axis is kept (tensor parallelism *within* a
    slice); every other axis is flattened into the slice index, so a
    ``(4, 2)`` ``("data", "model")`` mesh yields 4 two-device
    ``("model",)`` sub-meshes.  A mesh with no ``"model"`` axis yields one
    single-device slice per device.  These are the units the sharded
    gateway (serve/shard/) schedules over: each slice owns its own block
    pool + arena, placed on the sub-mesh's devices.
    """
    import numpy as np
    from jax.sharding import Mesh
    devs = np.asarray(mesh.devices)
    names = tuple(mesh.axis_names)
    if MODEL_AXIS in names:
        devs = np.moveaxis(devs, names.index(MODEL_AXIS), -1)
        flat = devs.reshape(-1, devs.shape[-1])
    else:
        flat = devs.reshape(-1, 1)
    return [Mesh(flat[i], (MODEL_AXIS,)) for i in range(flat.shape[0])]


# ==========================================================================
# ZeRO-1: optimizer state sharded over the DP group.
# ==========================================================================

def zero_shard_rule(spec: P, shape: tuple[int, ...],
                    mesh_shape: dict[str, int]) -> P:
    """Shard the largest free (unsharded) dim divisible by the full DP size
    across the DP axes; leave the spec untouched when nothing fits."""
    n = dp_size(mesh_shape)
    axes = dp_axes(mesh_shape)
    if n <= 1:
        return spec
    padded = tuple(spec) + (None,) * (len(shape) - len(spec))
    best = None
    for i, (dim, ax) in enumerate(zip(shape, padded)):
        if ax is None and dim > 0 and dim % n == 0:
            if best is None or dim > shape[best]:
                best = i
    if best is None:
        return spec
    out = list(padded)
    out[best] = axes[0] if len(axes) == 1 else axes
    return P(*out)


def zero_shard_specs(specs, params, mesh_shape: dict[str, int]):
    """Apply :func:`zero_shard_rule` leaf-for-leaf (params give the shapes)."""
    return jax.tree.map(
        lambda sp, p: zero_shard_rule(sp, p.shape, mesh_shape),
        specs, params, is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_specs, params, mesh_shape: dict[str, int], *,
                    master: bool = True):
    """Spec tree mirroring ``optim.init``: moments (and the f32 master copy)
    get the params' specs plus the ZeRO-1 DP sharding."""
    z = zero_shard_specs(param_specs, params, mesh_shape)
    out = {"step": P(), "m": z, "v": z}
    if master:
        out["master"] = z
    return out


# ==========================================================================
# Activation sharding hints.
# ==========================================================================

_ACTIVATION_MESH = None


@contextlib.contextmanager
def use_activation_mesh(mesh):
    """Within this context, :func:`hint` places real sharding constraints on
    ``mesh``; outside it, hint is an exact no-op (single-device tests)."""
    global _ACTIVATION_MESH
    prev = _ACTIVATION_MESH
    _ACTIVATION_MESH = mesh
    try:
        yield mesh
    finally:
        _ACTIVATION_MESH = prev


def hint(x, *axes):
    """Constrain activation ``x`` dim-by-dim.  Axis entries are mesh axis
    names, None (replicated), or the logical name "batch" which resolves to
    the DP axis group.  Non-divisible dims silently fall back to replication
    (the same contract as the param specs)."""
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return x
    ms = mesh_shape_dict(mesh)
    resolved = []
    for dim, ax in zip(x.shape, axes):
        if ax == "batch":
            ax = batch_spec_axis(ms, dim)
        elif ax is not None:
            size = ms.get(ax, 1)
            if size <= 1 or dim % size != 0:
                ax = None
        resolved.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
