"""Int8 gradient compression (chunked max-abs scaling) + error feedback.

Simulates the wire format of a compressed gradient all-reduce: gradients are
flattened, chunked, and quantized to int8 with a per-chunk f32 scale
(``chunk`` trades scale overhead for resolution: 1 f32 per ``chunk`` int8).
``int8_roundtrip`` is quantize-then-dequantize — what the receiving side
sees — so the training step can measure compression error end-to-end without
a real multi-host reduce.  ``int8_roundtrip_ef`` adds error feedback: the
quantization residual is carried to the next step, making the *running sum*
of compressed gradients track the true sum (the property that keeps SGD
convergent under biased compressors).
"""
from __future__ import annotations

import jax.numpy as jnp


def _roundtrip_f32(flat32: jnp.ndarray, chunk: int) -> jnp.ndarray:
    n = flat32.shape[0]
    pad = (-n) % chunk
    ch = jnp.pad(flat32, (0, pad)).reshape(-1, chunk)
    scale = jnp.max(jnp.abs(ch), axis=1, keepdims=True) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(ch / safe), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * safe          # all-zero chunks -> exactly 0
    return deq.reshape(-1)[:n]


def int8_roundtrip(g, chunk: int = 2048):
    """Quantize-dequantize ``g`` through the int8 wire format.

    Shape and dtype are preserved; max elementwise error is half an int8 LSB
    of the per-chunk scale (<= |g|_max / 254).
    """
    out = _roundtrip_f32(g.astype(jnp.float32).reshape(-1), int(chunk))
    return out.reshape(g.shape).astype(g.dtype)


def int8_roundtrip_ef(g, residual, chunk: int = 2048):
    """Error-feedback variant: compress ``g + residual``, return
    ``(compressed, new_residual)`` with the uncompressed remainder carried
    forward."""
    corrected = g.astype(jnp.float32) + residual.astype(jnp.float32)
    out32 = _roundtrip_f32(corrected.reshape(-1), int(chunk)).reshape(g.shape)
    new_res = (corrected - out32).astype(residual.dtype)
    return out32.astype(g.dtype), new_res
