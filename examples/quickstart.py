"""Quickstart: the paper's stochastic arithmetic in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import arith, bitstream as bs, energy, sng
from repro.core.sc_layer import SCConfig, sc_dot_sign
from repro.kernels import ops

print("=" * 64)
print("1. Streams: a stochastic number is a probability-coded bit-stream")
N = 32
x = sng.ramp_stream(jnp.asarray(20), N)       # 20/32 via ramp-compare A2S
w = sng.vdc_stream(jnp.asarray(8), N)         # 8/32 via low-discrepancy SNG
print(f"   x = {bs.value(x, N):.3f} (thermometer)  w = {bs.value(w, N):.3f}")

print("2. Multiply = AND gate; popcount(x & w)/N ~= x*w")
prod = arith.mult(x, w)
print(f"   x*w = {bs.value(prod, N):.4f}  (exact {20/32 * 8/32:.4f})")

print("3. The paper's TFF adder: (x + w)/2 EXACTLY (s0 picks rounding)")
z, _ = arith.tff_add_packed(x, w, N, s0=0)
print(f"   (x+w)/2 = {bs.value(z, N):.4f}  (exact {(20/32 + 8/32)/2:.4f})")

print("4. A whole dot product (784-unit engine style), three equivalent ways")
rng = np.random.default_rng(0)
xv = jnp.asarray(rng.random((1, 25)), jnp.float32)       # a 5x5 window
wv = jnp.asarray(rng.normal(0, 0.4, (25, 4)), jnp.float32)
cfg = SCConfig(bits=5)
out = sc_dot_sign(xv, wv, cfg, impl="table")
out2 = sc_dot_sign(xv, wv, cfg, impl="streams")
print(f"   sign(x . w) table path  : {np.asarray(out)[0]}")
print(f"   sign(x . w) stream path : {np.asarray(out2)[0]}  (bit-identical)")

print("5. Same datapath as the Pallas TPU kernel (interpret mode on CPU)")
from repro.core import sc_layer
xl = sc_layer.quantize_levels(xv, 5)
pos, neg, _ = sc_layer.quantize_weights(wv, 5)
kp = ops.sc_dot_from_levels(xl, pos, 5)
tp = sc_layer.counts_via_table(xl, pos, cfg)
print(f"   kernel == table counts: {bool((np.asarray(kp) == np.asarray(tp)).all())}")

print("6. Why bother: the energy model (Table 3), 65nm-calibrated")
for bits in (8, 4, 2):
    r = energy.report(bits)
    print(f"   {bits}-bit: SC {r.sc_energy_nj:7.2f} nJ/frame vs binary "
          f"{r.bin_energy_nj:7.2f} nJ/frame -> {r.efficiency_gain:5.2f}x")
print("=" * 64)
