"""Serve a small LM with batched requests: prefill a batch of prompts, then
greedy-decode continuations token-by-token through the KV cache engine.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch stablelm-3b]
      [--batch 4] [--prompt-len 32] [--gen 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.tokens import batch_at
from repro.models import lm
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.smoke_config(args.arch)
    params, _ = lm.init(jax.random.key(0), cfg, {})
    print(f"serving {cfg.name}: {lm.count_params(cfg)/1e6:.1f}M params, "
          f"batch={args.batch}")

    prompts = batch_at(0, 0, args.batch, args.prompt_len, cfg.vocab)["tokens"]
    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.family == "encdec":
        batch["enc_embed"] = jnp.zeros((args.batch, cfg.enc_len, cfg.d_model),
                                       jnp.bfloat16)
    if cfg.family == "vlm":
        batch["vision_embed"] = jnp.zeros(
            (args.batch, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(lambda p, b: engine.prefill(cfg, p, b))
    decode = jax.jit(lambda p, c, t: engine.decode_step(cfg, p, c, t))

    t0 = time.time()
    cache, logits = prefill(params, batch)
    # grow attention caches to prompt+gen
    for k in ("k", "v", "kx_self", "vx_self"):
        if k in cache:
            pad = [(0, 0)] * cache[k].ndim
            pad[-3] = (0, args.gen)
            cache[k] = jnp.pad(cache[k], pad)
    print(f"prefill {args.prompt_len} tokens x {args.batch}: "
          f"{time.time()-t0:.2f}s")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    tok = jnp.minimum(tok, cfg.vocab - 1)
    generated = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        cache, logits = decode(params, cache, tok)
        tok = jnp.minimum(jnp.argmax(logits, -1)[:, None].astype(jnp.int32),
                          cfg.vocab - 1)
        generated.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(generated, axis=1)
    print(f"decoded {args.gen} tokens x {args.batch} in {dt:.2f}s "
          f"({args.batch*(args.gen-1)/max(dt,1e-9):.1f} tok/s, "
          f"{1000*dt/(args.gen-1):.0f} ms/step)")
    for i in range(min(2, args.batch)):
        print(f"  request {i}: prompt tail {prompts[i,-5:].tolist()} -> "
              f"generated {gen[i,:10].tolist()}")


if __name__ == "__main__":
    main()
