"""Train a ~100M-param LM for a few hundred steps on the deterministic token
pipeline — the end-to-end training driver over the public API (mesh, sharded
init, grad-accum train step, async checkpoints).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()
    # ~100M params: a width-512, 8-layer llama-style decoder
    import repro.configs.stablelm_3b as base
    import repro.models.lm as lm
    import dataclasses
    cfg = dataclasses.replace(
        base.config(), name="lm-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=8, d_head=64, d_ff=1536, vocab=50304, remat="none")
    import repro.configs as configs
    configs.ALIASES["lm-100m"] = "lm-100m"  # transient registration

    # drive the launcher directly with the custom config
    import jax, jax.numpy as jnp, numpy as np, time
    from jax.sharding import PartitionSpec as P
    from repro.dist import sharding as shd
    from repro.launch.mesh import make_mesh
    from repro.train import optim
    from repro.train.step import METRICS_KEYS, TrainConfig, make_train_step
    from repro.data.tokens import TokenPipeline
    from repro.ckpt import manager as ckpt

    print(f"params: {lm.count_params(cfg)/1e6:.1f}M")
    mesh = make_mesh((1, 1), ("data", "model"))
    ms = shd.mesh_shape_dict(mesh)
    tcfg = TrainConfig(microbatches=1, adamw=optim.AdamWConfig(
        lr=3e-4, weight_decay=0.1, grad_clip=1.0))
    params, specs = lm.init(jax.random.key(0), cfg, ms)
    opt = optim.init(params, tcfg.adamw)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    pipe = TokenPipeline(0, 8, 512, cfg.vocab)
    mgr = ckpt.CheckpointManager(args.ckpt_dir, keep=2, save_interval=100)
    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        params, opt, m = step_fn(params, opt, batch)
        if step % 20 == 0:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"({(time.time()-t0)/(step+1)*1000:.0f} ms/step)")
        if mgr.should_save(step):
            mgr.save_async(step, (params, opt))
    mgr.wait()
    print(f"final loss {float(m['loss']):.4f} — done")


if __name__ == "__main__":
    main()
