"""Near-sensor serving gateway, end to end: sensor fleet -> micro-batcher ->
SC/binary frontend offload -> slot-batched LM decode -> telemetry report.

Run:  python examples/serve_sensors.py --endpoints 64 --duration 5
      [--frontend sc|binary|both] [--bits 4] [--rate 4.0]
      [--lm-arch rwkv6-7b] [--no-lm]

Prints throughput, p50/p99 latency, J/inference and link bytes/frame per
frontend — the sc frontend moves fewer bytes and burns less energy per
frame, which is the paper's near-sensor claim as a measured quantity.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serve.gateway import frontend as fe  # noqa: E402
from repro.serve.gateway.gateway import (GatewayConfig,  # noqa: E402
                                         MicroBatchGateway)
from repro.serve.gateway.sensors import FleetConfig, SensorFleet  # noqa: E402
from repro.serve.spec import ServeSpec, make_gateway  # noqa: E402


def run_frames(events, frontend: str, bits: int, duration: float,
               tracer=None, metrics=None, slo=None, flight=None,
               incident=None, service_ms: float | None = None) -> dict:
    spec = fe.FrontendSpec(mode=frontend, bits=bits)
    cfg = GatewayConfig() if service_ms is None else \
        GatewayConfig(service_model="fixed",
                      fixed_service_s=service_ms / 1e3)
    gw = MicroBatchGateway(cfg, spec)
    gw.warmup()
    tel = gw.run(events, tracer=tracer, metrics=metrics, slo=slo,
                 flight=flight, incident=incident)
    tel.assert_conserved()
    if tracer is not None:
        tracer.assert_energy_conserved(tel)
    rep = tel.report(duration, kind="frame")
    rep["link_bytes_per_frame"] = fe.link_bytes_per_frame(spec)
    rep["compile_counts"] = gw.compile_counts()
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--endpoints", type=int, default=64)
    ap.add_argument("--duration", type=float, default=5.0)
    ap.add_argument("--frontend", default="both",
                    choices=("sc", "binary", "both"))
    ap.add_argument("--bits", type=int, default=4,
                    choices=range(2, 9),
                    help="stream-length exponent (energy model: 2..8)")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="mean frames/s per endpoint")
    ap.add_argument("--lm-arch", default="rwkv6-7b")
    ap.add_argument("--no-lm", action="store_true",
                    help="skip the token-prompt path")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV slots (block pool + prefix sharing); "
                         "no-op for rwkv, which has O(1) state")
    ap.add_argument("--backend", default=None,
                    choices=("gather", "xla", "pallas", "cascade"),
                    help="paged decode-tick attention dataflow (with "
                         "--paged); default probes the platform.  "
                         "'cascade' attends shared radix prefixes once "
                         "per group instead of once per lane")
    ap.add_argument("--trace", action="store_true",
                    help="record per-request lifecycle spans + interval "
                         "metrics and export a Chrome trace-event JSON "
                         "(open in chrome://tracing or ui.perfetto.dev)")
    ap.add_argument("--trace-out", default="trace.json",
                    help="trace output path (with --trace); interval "
                         "metrics land next to it as <stem>_metrics.jsonl")
    ap.add_argument("--slo", action="store_true",
                    help="attach the SLO burn-rate monitor (SRE multi-window "
                         "ladder scaled to --duration): prints the run's "
                         "health verdict and any ok/warn/critical "
                         "transitions")
    ap.add_argument("--slo-ttft-ms", type=float, default=200.0,
                    help="TTFT objective target (with --slo)")
    ap.add_argument("--slo-queue-ms", type=float, default=100.0,
                    help="queue-wait objective target (with --slo)")
    ap.add_argument("--health-out", default=None,
                    help="write the run's health surface (metrics + SLO burn "
                         "state) as an OpenMetrics text exposition")
    ap.add_argument("--flight", action="store_true",
                    help="attach the always-on bounded flight recorder "
                         "(reservoir-sampled spans + exact tails; works "
                         "without --trace — spans flow through a "
                         "retention-free tracer into the ring)")
    ap.add_argument("--incident-dir", default=None,
                    help="arm incident auto-capture: SLO warn->critical, "
                         "drop bursts, energy mismatches write "
                         "schema-validated debug bundles here (inspect "
                         "with python -m repro.serve.obs.incident)")
    ap.add_argument("--service-ms", type=float, default=None,
                    help="pin the frame-path service time (fixed service "
                         "model) — deterministic overload for incident/SLO "
                         "demos and CI")
    args = ap.parse_args()

    tracer = metrics = slo_mon = flight = incident = None
    if args.trace or args.slo or args.health_out or args.flight \
            or args.incident_dir:
        from repro.serve import obs
        metrics = obs.MetricsRegistry(interval_s=max(args.duration / 50,
                                                     1e-3))
    if args.trace:
        tracer = obs.Tracer()
    if args.slo:
        slo_mon = obs.SLOMonitor(
            obs.SLOPolicy.default(period_s=args.duration,
                                  ttft_s=args.slo_ttft_ms / 1e3,
                                  queue_wait_s=args.slo_queue_ms / 1e3),
            tracer=tracer, metrics=metrics)
    if args.flight or args.incident_dir:
        flight = obs.FlightRecorder()

    prompt_frac = 0.0 if args.no_lm else 0.125
    fleet = SensorFleet(FleetConfig(
        n_endpoints=args.endpoints, frame_rate_hz=args.rate,
        prompt_fraction=prompt_frac))
    events = fleet.events(args.duration)
    n_frames = sum(a.kind == "frame" for a in events)
    n_prompts = len(events) - n_frames
    print(f"fleet: {args.endpoints} endpoints, "
          f"~{fleet.offered_load_hz():.0f} req/s offered, "
          f"{n_frames} frames + {n_prompts} prompts over "
          f"{args.duration:.0f}s (virtual)")

    # -- frame path: micro-batched hybrid LeNet, sc vs binary offload -------
    frontends = ("sc", "binary") if args.frontend == "both" \
        else (args.frontend,)
    # one obs attachment (tracer/metrics/SLO monitor), one serving path:
    # the LM prompt path when it runs (the full lifecycle —
    # queue/prefill/decode — is the richer surface), else the first frame
    # frontend
    lm_path = bool(not args.no_lm and n_prompts)
    trace_lm = bool(args.trace and lm_path)
    # exactly one serving path owns the incident pipeline (it subscribes
    # to the SLO pressure signal at construction): the LM gateway builds
    # its own via ServeSpec(incident_dir=...); the frame path gets one
    # here only when it is the obs surface
    if args.incident_dir and not lm_path:
        incident = obs.IncidentCapture(args.incident_dir, flight=flight,
                                       slo=slo_mon, metrics=metrics)
    reports = {}
    for i, f in enumerate(frontends):
        frame_obs = not lm_path and i == 0
        reports[f] = run_frames(events, f, args.bits, args.duration,
                                tracer=tracer if frame_obs else None,
                                metrics=metrics if frame_obs else None,
                                slo=slo_mon if frame_obs else None,
                                flight=flight if frame_obs else None,
                                incident=incident if frame_obs else None,
                                service_ms=args.service_ms)
        r = reports[f]
        if not r["completed"]:
            print(f"[{f:6s}] no frames completed "
                  f"(offered {n_frames}, dropped {r['dropped']})")
            continue
        print(f"[{f:6s}] {r['throughput_hz']:7.1f} frames/s  "
              f"p50 {r['p50_latency_ms']:6.2f} ms  "
              f"p99 {r['p99_latency_ms']:6.2f} ms  "
              f"{r['mean_energy_nj']:7.2f} nJ/inference "
              f"({r['j_per_inference']:.3e} J)  "
              f"link {r['link_bytes_per_frame']:4d} B/frame  "
              f"dropped {r['dropped']}")
    if len(reports) == 2 and all(r["completed"] for r in reports.values()):
        s, b = reports["sc"], reports["binary"]
        assert s["link_bytes_per_frame"] < b["link_bytes_per_frame"]
        print(f"sc frontend: {b['link_bytes_per_frame']/s['link_bytes_per_frame']:.1f}x "
              f"fewer link bytes/frame, "
              f"{b['mean_energy_nj']/s['mean_energy_nj']:.1f}x lower "
              f"energy/inference than the binary partition")

    # -- LM path: prompts through the family-generic slot batcher -----------
    if not args.no_lm and n_prompts:
        import jax.numpy as jnp
        cfg = configs.smoke_config(args.lm_arch)
        params, _ = lm.init(jax.random.key(0), cfg, {})
        extras = None              # modality stubs for encdec/vlm prefill
        if cfg.family == "encdec":
            extras = lambda: {"enc_embed": jnp.zeros(       # noqa: E731
                (1, cfg.enc_len, cfg.d_model), jnp.bfloat16)}
        elif cfg.family == "vlm":
            extras = lambda: {"vision_embed": jnp.zeros(    # noqa: E731
                (1, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)}
        paged = args.paged and cfg.family != "rwkv"
        spec = ServeSpec(n_slots=args.slots, max_len=64, paged=paged,
                         block_size=8, backend=args.backend if paged
                         else None, max_new_tokens=8,
                         tracer=tracer if trace_lm else None,
                         metrics=metrics, slo=slo_mon, flight=flight,
                         incident_dir=args.incident_dir)
        pgw = make_gateway(cfg, params, spec, extras=extras)
        if args.incident_dir:
            incident = pgw.incident
        pgw.warmup(fleet.cfg.prompt_lens, cfg.vocab)
        tel = pgw.run(events)
        if trace_lm:
            tracer.assert_energy_conserved(tel)
        r = tel.report(args.duration, kind="prompt")
        print(f"[lm:{cfg.family}] {r['completed']} prompts  "
              f"{r['throughput_hz']:6.1f} req/s  "
              f"p50 {r['p50_latency_ms']:6.1f} ms  "
              f"p99 {r['p99_latency_ms']:6.1f} ms  "
              f"{r.get('j_per_inference', 0.0):.2e} J/req  "
              f"dropped {r['dropped']}  "
              f"(slot batcher: {args.slots} slots, "
              f"family={cfg.family}, kv={'paged' if paged else 'dense'})")
        if paged and "pool" in r:
            p = r["pool"]
            print(f"[lm:pool] peak {p['peak_blocks_in_use']}"
                  f"/{p['num_blocks']} blocks in use, "
                  f"peak {p['peak_bytes_saved_vs_dense'] / 1024:.0f} KiB "
                  f"saved vs dense, "
                  f"{p['blocks_cached']} cached at drain, "
                  f"prefix hit rate {p['prefix_hit_rate']:.0%}, "
                  f"{p['evictions']} evictions, "
                  f"{p['cow_copies']} CoW copies")
            skipped = p.get("prefill_tokens_skipped", 0)
            if p.get("prefill_tokens_total"):
                print(f"[lm:prefill] {skipped}"
                      f"/{p['prefill_tokens_total']} prompt tokens "
                      f"skipped via prefix-hit chunked prefill "
                      f"({r.get('prefill_energy_saved_nj', 0.0):.1f} nJ "
                      f"frontend energy saved)")
        if "ttft_p50_ms" in r:
            print(f"[lm:slo] ttft p50 {r['ttft_p50_ms']:.1f} / "
                  f"p99 {r['ttft_p99_ms']:.1f} ms  "
                  f"tpot p50 {r['tpot_p50_ms']:.2f} / "
                  f"p99 {r['tpot_p99_ms']:.2f} ms  "
                  f"queue-wait p99 "
                  f"{r.get('queue_wait_p99_ms', 0.0):.1f} ms  "
                  f"(n={r['slo_n_samples']})")

    # -- health verdict + OpenMetrics exposition ----------------------------
    if slo_mon is not None:
        rep = slo_mon.report()
        burns = "  ".join(f"burn_{k}={v:.2f}"
                          for k, v in sorted(rep["burns"].items()))
        print(f"[health] state={rep['state']}  "
              f"transitions={len(rep['transitions'])}  {burns}")
        for tr_ in rep["transitions"]:
            print(f"[health]   t={tr_['t']:.3f}s {tr_['from']} -> "
                  f"{tr_['to']} (worst: {tr_['objective']})")
    if args.health_out:
        # the scrape surface must declare what the run promised: cascade
        # runs must expose the repro_cascade_* grouping families
        require = None
        if args.backend == "cascade" and lm_path and args.paged:
            require = [f"repro_cascade_{k}" for k in
                       ("groups", "grouped_lanes", "prefix_rows",
                        "prefix_rows_flat")]
        text = obs.write_openmetrics(args.health_out, metrics, slo_mon,
                                     require=require)
        print(f"[health] {len(text.splitlines())} OpenMetrics lines "
              f"(schema-validated"
              + (f", {len(require)} required families" if require else "")
              + f") -> {args.health_out}")

    # -- flight recorder + incident forensics -------------------------------
    if flight is not None:
        acct = flight.snapshot()["accounting"]
        print(f"[flight] ring: {acct['spans_kept']}/{acct['spans_seen']} "
              f"spans (reservoir), {acct['instants_kept']}"
              f"/{acct['instants_seen']} instants, "
              f"{acct['samples_kept']}/{acct['samples_seen']} "
              f"metric samples retained")
    if incident is not None:
        if incident.captures:
            for c in incident.captures:
                print(f"[incident] t={c['t']:.3f}s reason={c['reason']} "
                      f"-> {c['path']}")
            print(f"[incident] inspect with: python -m "
                  f"repro.serve.obs.incident inspect "
                  f"{incident.captures[0]['path']}")
        else:
            print(f"[incident] no triggers fired; bundles would land in "
                  f"{args.incident_dir}")

    # -- trace export: Perfetto-loadable, schema-validated ------------------
    if args.trace:
        out = pathlib.Path(args.trace_out)
        obj = obs.write_chrome_trace(str(out), tracer, metrics)
        mpath = out.with_name(out.stem + "_metrics.jsonl")
        n = obs.write_metrics_jsonl(str(mpath), metrics)
        print(f"[trace] {len(obj['traceEvents'])} events -> {out} "
              f"(Chrome trace-event JSON, schema-validated; open in "
              f"ui.perfetto.dev); {n} metric snapshots -> {mpath}")


if __name__ == "__main__":
    main()
