"""End-to-end driver for the paper's system (its 'kind' is near-sensor
inference): pretrain LeNet-5 float -> swap the first layer into the
stochastic domain -> retrain the binary remainder -> report accuracy +
energy, reproducing the hybrid pipeline of Fig. 3.

Run:  PYTHONPATH=src python examples/near_sensor_lenet.py [--bits 4]
      [--steps 400] [--full-lenet]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import energy, hybrid
from repro.core.sc_layer import SCConfig
from repro.data import mnist_synth
from repro.models import lenet
from repro.train import optim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--retrain-steps", type=int, default=250)
    ap.add_argument("--full-lenet", action="store_true",
                    help="paper-size LeNet (32/64 filters); default reduced")
    args = ap.parse_args()

    cfg = (lenet.LeNetConfig() if args.full_lenet
           else lenet.LeNetConfig(conv1_filters=16, conv2_filters=32,
                                  dense=128))
    xtr, ytr, xte, yte = mnist_synth.dataset(6000, 1500)
    print(f"LeNet-5 ({cfg.conv1_filters}/{cfg.conv2_filters} filters), "
          f"synthetic digit set {len(xtr)}/{len(xte)} (offline MNIST stand-in)")

    # -- stage 1: float pretraining (paper: TF/Keras; here pure JAX) --------
    params = lenet.init(jax.random.key(0), cfg)
    opt_cfg = optim.AdamWConfig(lr=1e-3)
    opt = optim.init(params, opt_cfg)
    key = jax.random.key(1)
    t0 = time.time()
    for step, (xb, yb) in enumerate(
            mnist_synth.batches(xtr, ytr, 64, 0, args.steps)):
        key, sub = jax.random.split(key)
        params, opt, loss = hybrid.float_train_step(
            params, opt, jnp.asarray(xb), jnp.asarray(yb), sub, cfg, opt_cfg)
        if step % 100 == 0:
            print(f"  pretrain step {step:4d} loss {float(loss):.3f}")
    acc_float = hybrid.evaluate(params, xte, yte, cfg,
                                hybrid.HybridConfig(mode="float"))
    print(f"float baseline: {100*(1-acc_float):.2f}% misclassification "
          f"({time.time()-t0:.0f}s)")

    # -- stage 2: swap first layer into the stochastic domain ---------------
    hcfg = hybrid.HybridConfig(mode="sc",
                               sc=SCConfig(bits=args.bits, adder="tff"))
    feats_tr = hybrid.cache_first_layer(params, xtr, hcfg)
    feats_te = hybrid.cache_first_layer(params, xte, hcfg)
    acc_before = hybrid.evaluate_cached(params, feats_te, yte, cfg)
    print(f"hybrid @{args.bits}-bit BEFORE retraining: "
          f"{100*(1-acc_before):.2f}%")

    # -- stage 3: retrain the binary remainder ------------------------------
    params_rt = hybrid.retrain_tail(params, feats_tr, ytr, cfg,
                                    steps=args.retrain_steps, batch=128)
    acc_after = hybrid.evaluate_cached(params_rt, feats_te, yte, cfg)
    print(f"hybrid @{args.bits}-bit AFTER retraining:  "
          f"{100*(1-acc_after):.2f}%  "
          f"(float {100*(1-acc_float):.2f}%)")

    # -- energy story --------------------------------------------------------
    r = energy.report(args.bits)
    print(f"energy @{args.bits}-bit: SC {r.sc_energy_nj:.2f} nJ/frame vs "
          f"binary {r.bin_energy_nj:.2f} -> {r.efficiency_gain:.1f}x saving")


if __name__ == "__main__":
    main()
